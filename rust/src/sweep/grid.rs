//! Grid construction: enumerate framework × model-set × strategy ×
//! scenario-mode × `empty_cache`-policy × algorithm × model-sharing ×
//! allocator-config combinations into a flat list of [`SweepCell`]s with
//! deterministic per-cell seeds.

use crate::alloc::AllocatorConfig;
use crate::experiment::RTX3090_HBM;
use crate::frameworks::{FrameworkKind, FrameworkProfile};
use crate::policy::EmptyCachePolicy;
use crate::rlhf::cost::GpuSpec;
use crate::rlhf::models::RlhfModelSet;
use crate::rlhf::program::{Algo, Sharing};
use crate::rlhf::sim::{ScenarioMode, SimScenario};
use crate::strategies::StrategyConfig;
use std::sync::Arc;

/// How the grid assigns the response-length-sampling seed to each cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeedPolicy {
    /// Every cell uses the same seed — what the paper presets do, so a
    /// grid run reproduces the serial Table-1/2 numbers exactly.
    Fixed(u64),
    /// Each cell derives a distinct seed from the base and its key, stable
    /// across runs and independent of worker scheduling.
    PerCell(u64),
}

/// One fully-resolved experiment of a sweep: everything a worker needs to
/// run it, plus the labels the report prints.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// `framework/model/strategy/mode/policy` — the stable identity used
    /// by filters, seeds and reports. Grids with a non-PPO algorithm axis
    /// append `/algo`, a non-separate sharing axis `/sharing`, and a
    /// non-default allocator axis `/alloc_label`, as extra components (in
    /// that order).
    pub key: String,
    pub framework: String,
    pub model: String,
    pub strategy: String,
    pub mode: ScenarioMode,
    pub policy: EmptyCachePolicy,
    /// RLHF algorithm of the cell (`ppo` unless the grid's algorithm
    /// axis says otherwise).
    pub algo: Algo,
    /// Model-sharing placement of the cell (`separate` unless the grid's
    /// sharing axis says otherwise).
    pub sharing: Sharing,
    /// Display label of the allocator configuration ("default" unless the
    /// grid's allocator axis says otherwise).
    pub alloc_label: String,
    /// Allocator tunables for this cell's simulated GPU.
    pub alloc_cfg: AllocatorConfig,
    pub scenario: SimScenario,
    /// Device capacity in bytes for this cell's simulated GPU.
    pub capacity: u64,
}

type Customizer = Arc<dyn Fn(&mut SimScenario) + Send + Sync>;

/// Builder for a sweep: configure axes, filters and per-cell seeding, then
/// [`SweepGrid::build`] the cartesian product into [`SweepCell`]s.
///
/// Defaults mirror the paper's RTX-3090 testbed: DeepSpeed-Chat, the
/// OPT-1.3b/350m model pair, strategy "None", policy `Never`, the full
/// pipeline, 3 PPO steps on a world of 4, 24 GiB capacity, and the
/// presets' fixed seed `0x5EED`.
#[derive(Clone)]
pub struct SweepGrid {
    frameworks: Vec<FrameworkKind>,
    model_sets: Vec<(String, RlhfModelSet)>,
    strategies: Vec<(String, StrategyConfig)>,
    policies: Vec<EmptyCachePolicy>,
    allocators: Vec<(String, AllocatorConfig)>,
    modes: Vec<ScenarioMode>,
    algos: Vec<Algo>,
    sharings: Vec<Sharing>,
    steps: u64,
    world: u64,
    capacity: u64,
    gpu: GpuSpec,
    seed: SeedPolicy,
    include: Vec<String>,
    exclude: Vec<String>,
    customize: Option<Customizer>,
    extra: Vec<SweepCell>,
    skip_unsupported: bool,
}

impl Default for SweepGrid {
    fn default() -> Self {
        Self::new()
    }
}

impl SweepGrid {
    pub fn new() -> SweepGrid {
        SweepGrid {
            frameworks: vec![FrameworkKind::DeepSpeedChat],
            model_sets: vec![("OPT".to_string(), RlhfModelSet::opt())],
            strategies: vec![("None".to_string(), StrategyConfig::none())],
            policies: vec![EmptyCachePolicy::Never],
            allocators: vec![("default".to_string(), AllocatorConfig::default())],
            modes: vec![ScenarioMode::Full],
            algos: vec![Algo::Ppo],
            sharings: vec![Sharing::Separate],
            steps: 3,
            world: 4,
            capacity: RTX3090_HBM,
            gpu: GpuSpec::rtx3090(),
            seed: SeedPolicy::Fixed(0x5EED),
            include: Vec::new(),
            exclude: Vec::new(),
            customize: None,
            extra: Vec::new(),
            skip_unsupported: true,
        }
    }

    pub fn frameworks(mut self, fws: impl IntoIterator<Item = FrameworkKind>) -> Self {
        self.frameworks = fws.into_iter().collect();
        self
    }

    /// Model pairs with display labels, e.g. `("OPT", RlhfModelSet::opt())`.
    pub fn model_sets(
        mut self,
        sets: impl IntoIterator<Item = (impl Into<String>, RlhfModelSet)>,
    ) -> Self {
        self.model_sets = sets.into_iter().map(|(l, m)| (l.into(), m)).collect();
        self
    }

    /// Strategy rows with display labels, in paper-table order.
    pub fn strategies(
        mut self,
        rows: impl IntoIterator<Item = (impl Into<String>, StrategyConfig)>,
    ) -> Self {
        self.strategies = rows.into_iter().map(|(l, s)| (l.into(), s)).collect();
        self
    }

    pub fn policies(mut self, ps: impl IntoIterator<Item = EmptyCachePolicy>) -> Self {
        self.policies = ps.into_iter().collect();
        self
    }

    /// Allocator-config axis (`PYTORCH_CUDA_ALLOC_CONF` emulations) with
    /// display labels. Labels other than `"default"` are appended to the
    /// cell key as a sixth `/`-component, so single-config grids keep the
    /// legacy five-part keys the paper presets and tests rely on.
    pub fn allocator_configs(
        mut self,
        cfgs: impl IntoIterator<Item = (impl Into<String>, AllocatorConfig)>,
    ) -> Self {
        self.allocators = cfgs.into_iter().map(|(l, c)| (l.into(), c)).collect();
        self
    }

    pub fn modes(mut self, ms: impl IntoIterator<Item = ScenarioMode>) -> Self {
        self.modes = ms.into_iter().collect();
        self
    }

    /// Algorithm axis (`ppo`/`grpo`/`remax`/`dpo`). Non-PPO algorithms
    /// are appended to the cell key so single-algorithm grids keep the
    /// legacy five-part keys the paper presets and tests rely on.
    pub fn algos(mut self, al: impl IntoIterator<Item = Algo>) -> Self {
        self.algos = al.into_iter().collect();
        self
    }

    /// Model-sharing axis (`separate`/`lora`/`hydra`/`frozen-shared`/`perl`).
    /// Non-separate placements are appended to the cell key (after the
    /// algo component, before the allocator label) so single-placement
    /// grids keep their legacy keys.
    pub fn sharings(mut self, sh: impl IntoIterator<Item = Sharing>) -> Self {
        self.sharings = sh.into_iter().collect();
        self
    }

    pub fn steps(mut self, steps: u64) -> Self {
        self.steps = steps;
        self
    }

    pub fn world(mut self, world: u64) -> Self {
        self.world = world;
        self
    }

    /// Simulated device capacity in bytes (e.g. [`crate::experiment::A100_HBM`]).
    pub fn capacity(mut self, bytes: u64) -> Self {
        self.capacity = bytes;
        self
    }

    pub fn gpu(mut self, gpu: GpuSpec) -> Self {
        self.gpu = gpu;
        self
    }

    pub fn seeds(mut self, policy: SeedPolicy) -> Self {
        self.seed = policy;
        self
    }

    /// Keep only cells whose key contains any of these substrings.
    pub fn include(mut self, pat: impl Into<String>) -> Self {
        self.include.push(pat.into());
        self
    }

    /// Drop cells whose key contains any of these substrings.
    pub fn exclude(mut self, pat: impl Into<String>) -> Self {
        self.exclude.push(pat.into());
        self
    }

    /// Post-process every cell's scenario (e.g. Table 2's longer
    /// sequences). Runs after the cell is materialized; filters act on
    /// the cell key, which is already fixed at that point.
    pub fn customize(mut self, f: impl Fn(&mut SimScenario) + Send + Sync + 'static) -> Self {
        self.customize = Some(Arc::new(f));
        self
    }

    /// Error (instead of silently skipping) when a framework does not
    /// support a strategy in the grid.
    pub fn strict(mut self) -> Self {
        self.skip_unsupported = false;
        self
    }

    /// Append one explicit cell outside the cartesian axes (e.g. the
    /// Appendix-B `generation()` variants). The scenario is taken as-is;
    /// the key is derived from the labels plus the scenario's mode/policy,
    /// and the cell runs at the grid's [`Self::capacity`] (resolved at
    /// [`Self::build`] time, so setter order doesn't matter).
    pub fn push_scenario(
        mut self,
        framework: impl Into<String>,
        model: impl Into<String>,
        strategy: impl Into<String>,
        scenario: SimScenario,
    ) -> Self {
        let (framework, model, strategy) = (framework.into(), model.into(), strategy.into());
        let mut key = format!(
            "{}/{}/{}/{}/{}",
            framework,
            model,
            strategy,
            scenario.mode.name(),
            scenario.policy.name()
        );
        if scenario.algo != Algo::Ppo {
            key.push('/');
            key.push_str(scenario.algo.name());
        }
        if scenario.sharing != Sharing::Separate {
            key.push('/');
            key.push_str(scenario.sharing.name());
        }
        self.extra.push(SweepCell {
            key,
            framework,
            model,
            strategy,
            mode: scenario.mode,
            policy: scenario.policy,
            algo: scenario.algo,
            sharing: scenario.sharing,
            alloc_label: "default".to_string(),
            alloc_cfg: AllocatorConfig::default(),
            capacity: self.capacity,
            scenario,
        });
        self
    }

    fn passes_filters(&self, key: &str) -> bool {
        if !self.include.is_empty() && !self.include.iter().any(|p| key.contains(p.as_str())) {
            return false;
        }
        !self.exclude.iter().any(|p| key.contains(p.as_str()))
    }

    /// Enumerate the grid into cells (framework → model → strategy → mode
    /// → policy order, then explicit [`Self::push_scenario`] cells).
    pub fn build(&self) -> Result<Vec<SweepCell>, String> {
        let mut cells: Vec<SweepCell> = Vec::new();
        for kind in &self.frameworks {
            let profile = FrameworkProfile::by_kind(*kind);
            for (mlabel, models) in &self.model_sets {
                for (slabel, strategy) in &self.strategies {
                    if !profile.supports(strategy) {
                        if self.skip_unsupported {
                            continue;
                        }
                        return Err(format!(
                            "{} does not support strategy '{slabel}'",
                            kind.name()
                        ));
                    }
                    for mode in &self.modes {
                        for policy in &self.policies {
                            for algo in &self.algos {
                                for sharing in &self.sharings {
                                    for (alabel, acfg) in &self.allocators {
                                        let scenario_key = format!(
                                            "{}/{}/{}/{}/{}",
                                            kind.name(),
                                            mlabel,
                                            slabel,
                                            mode.name(),
                                            policy.name()
                                        );
                                        let mut key = scenario_key.clone();
                                        if *algo != Algo::Ppo {
                                            key.push('/');
                                            key.push_str(algo.name());
                                        }
                                        if *sharing != Sharing::Separate {
                                            key.push('/');
                                            key.push_str(sharing.name());
                                        }
                                        if alabel != "default" {
                                            key.push('/');
                                            key.push_str(alabel);
                                        }
                                        if !self.passes_filters(&key) {
                                            continue;
                                        }
                                        let mut scenario = SimScenario {
                                            framework: profile.clone(),
                                            models: models.clone(),
                                            strategy: *strategy,
                                            world: self.world,
                                            policy: *policy,
                                            steps: self.steps,
                                            mode: *mode,
                                            algo: *algo,
                                            sharing: *sharing,
                                            gpu: self.gpu,
                                            seed: match self.seed {
                                                SeedPolicy::Fixed(s) => s,
                                                // Seeded from the *scenario*
                                                // key (without the algo,
                                                // sharing or allocator
                                                // suffixes): cells differing
                                                // only in those axes must
                                                // sample the identical
                                                // length-jitter stream, else
                                                // the measured axis delta is
                                                // confounded by seed noise.
                                                SeedPolicy::PerCell(base) => {
                                                    derive_seed(base, &scenario_key)
                                                }
                                            },
                                            len_jitter: kind.default_len_jitter(),
                                            roles: crate::rlhf::models::RoleSet::ALL,
                                            time_shared: crate::rlhf::models::RoleSet::EMPTY,
                                            rank: 0,
                                        };
                                        if let Some(f) = &self.customize {
                                            f(&mut scenario);
                                        }
                                        cells.push(SweepCell {
                                            key,
                                            framework: kind.name().to_string(),
                                            model: mlabel.clone(),
                                            strategy: slabel.clone(),
                                            mode: *mode,
                                            policy: *policy,
                                            algo: *algo,
                                            sharing: *sharing,
                                            alloc_label: alabel.clone(),
                                            alloc_cfg: acfg.clone(),
                                            scenario,
                                            capacity: self.capacity,
                                        });
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        cells.extend(
            self.extra
                .iter()
                .filter(|c| self.passes_filters(&c.key))
                .map(|c| SweepCell {
                    capacity: self.capacity,
                    ..c.clone()
                }),
        );
        Ok(cells)
    }
}

/// A named model set for CLI use: `opt` (OPT-1.3b policy / 350m value),
/// `gpt2` (GPT-2-XL / medium), `nano` (the real-compute test pair).
pub fn model_set_by_name(name: &str) -> Option<(String, RlhfModelSet)> {
    match name {
        "opt" => Some(("OPT".to_string(), RlhfModelSet::opt())),
        "gpt2" | "gpt-2" => Some(("GPT-2".to_string(), RlhfModelSet::gpt2())),
        "nano" => Some(("nano".to_string(), RlhfModelSet::nano())),
        _ => None,
    }
}

/// Derive a per-cell seed: mix the base with a hash of the cell key
/// through a SplitMix64 finalizer. Stable across runs and platforms;
/// independent of enumeration or scheduling order.
fn derive_seed(base: u64, key: &str) -> u64 {
    use std::hash::Hasher;
    let mut h = crate::util::fasthash::FastHasher::default();
    h.write(key.as_bytes());
    let mut z = (base ^ h.finish()).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cartesian_count_and_order() {
        let grid = SweepGrid::new()
            .strategies([
                ("None", StrategyConfig::none()),
                ("ZeRO-3", StrategyConfig::zero3()),
            ])
            .policies([EmptyCachePolicy::Never, EmptyCachePolicy::AfterBoth]);
        let cells = grid.build().unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].key, "DeepSpeed-Chat/OPT/None/full/never");
        assert_eq!(cells[1].key, "DeepSpeed-Chat/OPT/None/full/after_both");
        assert_eq!(cells[3].key, "DeepSpeed-Chat/OPT/ZeRO-3/full/after_both");
        // Presets reproduced: fixed seed, jitter off for DeepSpeed.
        assert_eq!(cells[0].scenario.seed, 0x5EED);
        assert!(!cells[0].scenario.len_jitter);
    }

    #[test]
    fn colossal_skips_zero1_unless_strict() {
        let grid = SweepGrid::new()
            .frameworks([FrameworkKind::ColossalChat])
            .strategies([
                ("ZeRO-1", StrategyConfig::zero1()),
                ("ZeRO-3", StrategyConfig::zero3()),
            ]);
        let cells = grid.build().unwrap();
        assert_eq!(cells.len(), 1);
        assert!(cells[0].key.contains("ZeRO-3"));
        assert!(cells[0].scenario.len_jitter, "colossal presets jitter");
        assert!(grid.clone().strict().build().is_err());
    }

    #[test]
    fn include_exclude_filter_keys() {
        let grid = SweepGrid::new()
            .strategies([
                ("None", StrategyConfig::none()),
                ("ZeRO-2", StrategyConfig::zero2()),
                ("ZeRO-3", StrategyConfig::zero3()),
            ])
            .include("ZeRO")
            .exclude("ZeRO-2");
        let cells = grid.build().unwrap();
        assert_eq!(cells.len(), 1);
        assert!(cells[0].key.contains("ZeRO-3"));
    }

    #[test]
    fn per_cell_seeds_are_stable_and_distinct() {
        let grid = SweepGrid::new()
            .strategies([
                ("None", StrategyConfig::none()),
                ("ZeRO-3", StrategyConfig::zero3()),
            ])
            .seeds(SeedPolicy::PerCell(42));
        let a = grid.build().unwrap();
        let b = grid.build().unwrap();
        let seeds: Vec<u64> = a.iter().map(|c| c.scenario.seed).collect();
        assert_eq!(seeds, b.iter().map(|c| c.scenario.seed).collect::<Vec<_>>());
        assert_ne!(seeds[0], seeds[1], "distinct keys get distinct seeds");
    }

    #[test]
    fn allocator_axis_suffixes_non_default_keys() {
        let expandable = AllocatorConfig {
            expandable_segments: true,
            ..AllocatorConfig::default()
        };
        let cells = SweepGrid::new()
            .allocator_configs([
                ("default", AllocatorConfig::default()),
                ("expandable", expandable.clone()),
            ])
            .build()
            .unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].key, "DeepSpeed-Chat/OPT/None/full/never");
        assert_eq!(cells[1].key, "DeepSpeed-Chat/OPT/None/full/never/expandable");
        assert_eq!(cells[0].alloc_label, "default");
        assert!(!cells[0].alloc_cfg.expandable_segments);
        assert!(cells[1].alloc_cfg.expandable_segments);
        // The axis participates in filters like every key component.
        let only = SweepGrid::new()
            .allocator_configs([
                ("default", AllocatorConfig::default()),
                ("expandable", expandable),
            ])
            .include("expandable")
            .build()
            .unwrap();
        assert_eq!(only.len(), 1);
        assert_eq!(only[0].alloc_label, "expandable");
    }

    #[test]
    fn algo_axis_suffixes_non_ppo_keys() {
        use crate::rlhf::program::Algo;
        let cells = SweepGrid::new()
            .algos([Algo::Ppo, Algo::Grpo, Algo::Dpo])
            .build()
            .unwrap();
        assert_eq!(cells.len(), 3);
        assert_eq!(cells[0].key, "DeepSpeed-Chat/OPT/None/full/never");
        assert_eq!(cells[1].key, "DeepSpeed-Chat/OPT/None/full/never/grpo");
        assert_eq!(cells[2].key, "DeepSpeed-Chat/OPT/None/full/never/dpo");
        assert_eq!(cells[0].algo, Algo::Ppo);
        assert_eq!(cells[1].scenario.algo, Algo::Grpo);
        // The axis participates in filters like every key component.
        let only = SweepGrid::new()
            .algos([Algo::Ppo, Algo::Grpo])
            .include("grpo")
            .build()
            .unwrap();
        assert_eq!(only.len(), 1);
        assert_eq!(only[0].algo, Algo::Grpo);
        // Algo precedes the allocator label in combined keys.
        let combined = SweepGrid::new()
            .algos([Algo::Grpo])
            .allocator_configs([(
                "expandable",
                AllocatorConfig {
                    expandable_segments: true,
                    ..AllocatorConfig::default()
                },
            )])
            .build()
            .unwrap();
        assert_eq!(
            combined[0].key,
            "DeepSpeed-Chat/OPT/None/full/never/grpo/expandable"
        );
    }

    #[test]
    fn per_cell_seeds_ignore_the_algo_suffix() {
        use crate::rlhf::program::Algo;
        // Cells differing only in algorithm sample the identical jitter
        // stream — the axis delta must not be confounded by seeds.
        let cells = SweepGrid::new()
            .algos([Algo::Ppo, Algo::Grpo])
            .seeds(SeedPolicy::PerCell(42))
            .build()
            .unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].scenario.seed, cells[1].scenario.seed);
    }

    #[test]
    fn sharing_axis_suffixes_non_separate_keys() {
        let cells = SweepGrid::new()
            .sharings([Sharing::Separate, Sharing::Lora, Sharing::Hydra])
            .build()
            .unwrap();
        assert_eq!(cells.len(), 3);
        assert_eq!(cells[0].key, "DeepSpeed-Chat/OPT/None/full/never");
        assert_eq!(cells[1].key, "DeepSpeed-Chat/OPT/None/full/never/lora");
        assert_eq!(cells[2].key, "DeepSpeed-Chat/OPT/None/full/never/hydra");
        assert_eq!(cells[0].sharing, Sharing::Separate);
        assert_eq!(cells[1].scenario.sharing, Sharing::Lora);
        // The axis participates in filters like every key component.
        let only = SweepGrid::new()
            .sharings([Sharing::Separate, Sharing::Hydra])
            .include("hydra")
            .build()
            .unwrap();
        assert_eq!(only.len(), 1);
        assert_eq!(only[0].sharing, Sharing::Hydra);
        // Suffix order: algo, then sharing, then allocator label.
        let combined = SweepGrid::new()
            .algos([Algo::Grpo])
            .sharings([Sharing::Lora])
            .allocator_configs([(
                "expandable",
                AllocatorConfig {
                    expandable_segments: true,
                    ..AllocatorConfig::default()
                },
            )])
            .build()
            .unwrap();
        assert_eq!(
            combined[0].key,
            "DeepSpeed-Chat/OPT/None/full/never/grpo/lora/expandable"
        );
    }

    #[test]
    fn per_cell_seeds_ignore_the_sharing_suffix() {
        // Cells differing only in the sharing placement replay the
        // identical workload — the placement delta must not be confounded
        // by seeds.
        let cells = SweepGrid::new()
            .sharings([Sharing::Separate, Sharing::Hydra])
            .seeds(SeedPolicy::PerCell(42))
            .build()
            .unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].scenario.seed, cells[1].scenario.seed);
    }

    #[test]
    fn push_scenario_suffixes_sharing() {
        let mut scn = SimScenario::colossal_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        scn.sharing = Sharing::Lora;
        let cells = SweepGrid::new()
            .push_scenario("ColossalChat", "OPT", "custom", scn)
            .build()
            .unwrap();
        assert_eq!(cells[1].key, "ColossalChat/OPT/custom/full/never/lora");
        assert_eq!(cells[1].sharing, Sharing::Lora);
    }

    #[test]
    fn per_cell_seeds_ignore_the_allocator_suffix() {
        // Cells differing only in allocator config replay the identical
        // workload — the knob's effect must not be confounded by seeds.
        let cells = SweepGrid::new()
            .allocator_configs([
                ("default", AllocatorConfig::default()),
                (
                    "expandable",
                    AllocatorConfig {
                        expandable_segments: true,
                        ..AllocatorConfig::default()
                    },
                ),
            ])
            .seeds(SeedPolicy::PerCell(42))
            .build()
            .unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].scenario.seed, cells[1].scenario.seed);
    }

    #[test]
    fn customize_applies_to_every_cell() {
        let cells = SweepGrid::new()
            .customize(|scn| scn.framework.prompt_len = 64)
            .build()
            .unwrap();
        assert!(cells.iter().all(|c| c.scenario.framework.prompt_len == 64));
    }

    #[test]
    fn push_scenario_appends_custom_cell() {
        let scn = SimScenario::colossal_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        let cells = SweepGrid::new()
            .strategies([("None", StrategyConfig::none())])
            .push_scenario("ColossalChat", "OPT", "custom-gen", scn)
            .build()
            .unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[1].key, "ColossalChat/OPT/custom-gen/full/never");
    }

    #[test]
    fn model_sets_by_name() {
        assert!(model_set_by_name("opt").is_some());
        assert!(model_set_by_name("gpt2").is_some());
        assert!(model_set_by_name("nope").is_none());
    }
}
