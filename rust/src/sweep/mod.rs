//! Parallel experiment sweep engine.
//!
//! The paper's contribution *is* a sweep — memory behaviour across
//! frameworks × strategies × models × `empty_cache` policies (Tables 1–2,
//! Figure 1) — so the experiment layer exposes exactly that shape:
//!
//! * [`SweepGrid`] enumerates the cartesian product of the axes — the
//!   four scenario axes plus an algorithm axis
//!   ([`crate::rlhf::program::Algo`]: PPO / GRPO / ReMax / DPO) and an
//!   allocator-config axis (`PYTORCH_CUDA_ALLOC_CONF` emulations, the
//!   planner's search space) — with include/exclude filters, per-cell
//!   deterministic seeds, and a `customize` hook for off-grid tweaks,
//!   into [`SweepCell`]s;
//! * [`SweepRunner`] shards the cells across a pool of worker threads —
//!   each worker owns its own allocator + profiler, so per-cell numbers
//!   are bit-identical whatever `--jobs` is;
//! * [`SweepReport`] aggregates: deterministic JSON-lines, a generic
//!   [`crate::report::table::TextTable`], and the paper's
//!   framework/model-blocked [`crate::report::paper::StrategyRow`] layout.
//!
//! Every paper command (`table1`, `table2`, `figure1`, `ablation`,
//! `gen-ablation`) is a thin grid definition over this engine, and the
//! `sweep` subcommand exposes user-defined grids from the CLI.
//!
//! # Example: a 2×2 grid, run on two workers
//!
//! ```
//! use rlhf_mem::policy::EmptyCachePolicy;
//! use rlhf_mem::strategies::StrategyConfig;
//! use rlhf_mem::sweep::{SweepGrid, SweepRunner};
//!
//! let cells = SweepGrid::new() // defaults: DeepSpeed-Chat / OPT / 24 GiB
//!     .strategies([
//!         ("None", StrategyConfig::none()),
//!         ("ZeRO-3", StrategyConfig::zero3()),
//!     ])
//!     .policies([EmptyCachePolicy::Never, EmptyCachePolicy::AfterBoth])
//!     .steps(1)
//!     .build()
//!     .unwrap();
//! assert_eq!(cells.len(), 4); // 2 strategies × 2 policies
//!
//! let report = SweepRunner::new(2).run(cells);
//! assert_eq!(report.cells.len(), 4);
//! // Paper-shaped rows: the after_both cells fill the empty_cache half.
//! let blocks = report.strategy_rows();
//! let rows = &blocks[0].2;
//! assert_eq!(rows.len(), 2);
//! assert!(rows[0].with_empty_cache.empty_cache_calls > 0);
//! ```

pub mod grid;
pub mod presets;
pub mod report;
pub mod runner;

pub use grid::{model_set_by_name, SeedPolicy, SweepCell, SweepGrid};
pub use report::SweepReport;
pub use runner::{CellResult, SweepRunner};
