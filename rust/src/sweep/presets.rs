//! The paper's canonical grids, shared by the CLI commands and the bench
//! harnesses so a row added to a table exists in exactly one place.

use super::grid::{SweepCell, SweepGrid};
use crate::experiment::A100_HBM;
use crate::frameworks::FrameworkKind;
use crate::mem::ModelArch;
use crate::policy::EmptyCachePolicy;
use crate::rlhf::cost::GpuSpec;
use crate::rlhf::models::RlhfModelSet;
use crate::strategies::StrategyConfig;

/// Table 1's three framework/model blocks (each row measured with and
/// without `empty_cache()`), as one flat cell list.
pub fn table1_cells(steps: u64) -> Result<Vec<SweepCell>, String> {
    let blocks = [
        (
            FrameworkKind::DeepSpeedChat,
            "OPT",
            RlhfModelSet::opt(),
            StrategyConfig::table1_deepspeed_rows(),
        ),
        (
            FrameworkKind::ColossalChat,
            "OPT",
            RlhfModelSet::opt(),
            StrategyConfig::table1_colossal_rows(),
        ),
        (
            FrameworkKind::ColossalChat,
            "GPT-2",
            RlhfModelSet::gpt2(),
            StrategyConfig::table1_colossal_rows(),
        ),
    ];
    let mut cells = Vec::new();
    for (kind, model, models, rows) in blocks {
        cells.extend(
            SweepGrid::new()
                .frameworks([kind])
                .model_sets([(model, models)])
                .strategies(rows)
                .policies([EmptyCachePolicy::Never, EmptyCachePolicy::AfterBoth])
                .steps(steps)
                .build()?,
        );
    }
    Ok(cells)
}

/// Table 2's grid: None vs ZeRO-3 on a 4×A100-80G node for OPT-1.3b,
/// OPT-6.7b and Llama-2-7b, each paired with the OPT-350m scorer, under
/// the A100-scale workload (longer sequences, larger rollout than the
/// 24 GiB box).
pub fn table2_cells(steps: u64) -> Result<Vec<SweepCell>, String> {
    let mut cells = Vec::new();
    for arch_name in ["opt-1.3b", "opt-6.7b", "llama-2-7b"] {
        let arch = ModelArch::by_name(arch_name).expect("table2 preset arch");
        let models = RlhfModelSet {
            policy_arch: arch,
            value_arch: ModelArch::opt_350m(),
        };
        cells.extend(
            SweepGrid::new()
                .frameworks([FrameworkKind::ColossalChat])
                .model_sets([(arch_name, models)])
                .strategies([
                    ("None", StrategyConfig::none()),
                    ("ZeRO-3", StrategyConfig::zero3()),
                ])
                .policies([EmptyCachePolicy::Never, EmptyCachePolicy::AfterBoth])
                .steps(steps)
                .capacity(A100_HBM)
                .gpu(GpuSpec::a100_80g())
                .customize(|scn| {
                    scn.framework.prompt_len = 256;
                    scn.framework.gen_len = 256;
                    scn.framework.rollout_batch = 64;
                    scn.framework.infer_micro_batch = 8;
                    scn.framework.train_micro_batch = 4;
                })
                .build()?,
        );
    }
    Ok(cells)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_grid_shape() {
        let cells = table1_cells(1).unwrap();
        // (7 DS rows + 5 CC rows + 5 CC/GPT-2 rows) × 2 policies.
        assert_eq!(cells.len(), 34);
        assert!(cells[0].key.starts_with("DeepSpeed-Chat/OPT/None"));
        assert!(cells.iter().all(|c| c.scenario.steps == 1));
    }

    #[test]
    fn table2_grid_shape() {
        let cells = table2_cells(2).unwrap();
        // 3 models × 2 strategies × 2 policies.
        assert_eq!(cells.len(), 12);
        assert!(cells.iter().all(|c| c.capacity == A100_HBM));
        assert!(cells.iter().all(|c| c.scenario.framework.rollout_batch == 64));
    }
}
