//! Sweep aggregation: JSON-lines dumps, a generic cell table, and the
//! paper-shaped [`StrategyRow`] grouping that `table1`/`table2` render.

use super::runner::CellResult;
use crate::obs::Telemetry;
use crate::report::paper::StrategyRow;
use crate::report::table::TextTable;
use crate::util::bytes::fmt_gib_paper;
use crate::util::schema;

/// All cell results of one sweep, in input (grid enumeration) order.
pub struct SweepReport {
    pub cells: Vec<CellResult>,
    /// Wall-clock of the whole sweep, seconds.
    pub wall_seconds: f64,
    /// Worker count the sweep actually used.
    pub jobs: usize,
}

impl SweepReport {
    /// Deterministic JSON-lines dump: the versioned schema header, then
    /// one line per cell, index order. Byte-identical for the same grid
    /// whatever `jobs` was.
    pub fn jsonl(&self) -> String {
        let mut out = schema::header_line("sweep");
        out.push('\n');
        for c in &self.cells {
            out.push_str(&c.jsonl_line());
            out.push('\n');
        }
        out
    }

    /// The run-telemetry ledger of this sweep. Counters are sums over the
    /// index-ordered cells — deterministic and `jobs`-independent — while
    /// the sweep's wall-clock lands in the (never-serialized) wall list.
    pub fn telemetry(&self) -> Telemetry {
        let mut t = Telemetry::new();
        t.add("cells", self.cells.len() as u64);
        t.add(
            "oom_cells",
            self.cells.iter().filter(|c| c.summary.oom).count() as u64,
        );
        for c in &self.cells {
            let s = &c.summary;
            t.add("num_allocs", s.num_allocs);
            t.add("cache_hits", s.num_cache_hits);
            t.add("cuda_mallocs", s.cuda_mallocs);
            t.add("empty_cache_calls", s.empty_cache_calls);
        }
        t.wall("sweep", self.wall_seconds);
        t
    }

    /// [`Self::jsonl`] plus one trailing `{"telemetry":{...}}` footer
    /// line. Still byte-identical for any `--jobs`.
    pub fn jsonl_with_telemetry(&self) -> String {
        let mut out = self.jsonl();
        out.push_str(&self.telemetry().footer_line());
        out.push('\n');
        out
    }

    /// Generic aggregated table (GiB columns, [`TextTable`]-compatible):
    /// one row per cell.
    pub fn to_table(&self) -> TextTable {
        let mut t = TextTable::new(&[
            "Cell",
            "Reserved",
            "Frag.",
            "Allocated",
            "Peak phase",
            "EC calls",
            "OOM",
        ]);
        for c in &self.cells {
            let s = &c.summary;
            t.row(vec![
                c.key.clone(),
                fmt_gib_paper(s.peak_reserved),
                fmt_gib_paper(s.frag),
                fmt_gib_paper(s.peak_allocated),
                s.peak_phase.name().to_string(),
                s.empty_cache_calls.to_string(),
                if s.oom { "yes" } else { "" }.to_string(),
            ]);
        }
        t
    }

    /// Group cells into the paper's table layout: one block per
    /// `(framework, model)` in first-seen order, one [`StrategyRow`] per
    /// strategy (per scenario mode — non-`full` modes get the mode
    /// appended to the row label so multi-mode grids don't collapse;
    /// non-PPO algorithms, non-separate sharing placements and
    /// non-default allocator configs likewise get their labels appended
    /// so those axes don't overwrite the stock rows).
    /// A cell with policy `never` fills the row's "original" half,
    /// `after_both` the "+ empty_cache" half; a row missing one half
    /// mirrors the other (so `never`-only grids still render).
    pub fn strategy_rows(&self) -> Vec<(String, String, Vec<StrategyRow>)> {
        let mut blocks: Vec<(String, String, Vec<StrategyRow>)> = Vec::new();
        for cell in &self.cells {
            let bi = match blocks
                .iter()
                .position(|(f, m, _)| *f == cell.framework && *m == cell.model)
            {
                Some(i) => i,
                None => {
                    blocks.push((cell.framework.clone(), cell.model.clone(), Vec::new()));
                    blocks.len() - 1
                }
            };
            let mut row_label = if cell.mode == "full" {
                cell.strategy.clone()
            } else {
                format!("{} [{}]", cell.strategy, cell.mode)
            };
            if cell.algo != "ppo" {
                row_label = format!("{} [{}]", row_label, cell.algo);
            }
            if cell.sharing != "separate" {
                row_label = format!("{} [{}]", row_label, cell.sharing);
            }
            if cell.alloc != "default" {
                row_label = format!("{} [{}]", row_label, cell.alloc);
            }
            let rows = &mut blocks[bi].2;
            let ri = match rows.iter().position(|r| r.strategy == row_label) {
                Some(i) => i,
                None => {
                    rows.push(StrategyRow {
                        strategy: row_label,
                        original: cell.summary.clone(),
                        with_empty_cache: cell.summary.clone(),
                    });
                    rows.len() - 1
                }
            };
            match cell.policy {
                "never" => rows[ri].original = cell.summary.clone(),
                "after_both" => rows[ri].with_empty_cache = cell.summary.clone(),
                // Other placements don't map onto the two-column layout;
                // they still seeded the row when it was created above.
                _ => {}
            }
        }
        blocks
    }

    /// Look a cell up by its grid key.
    pub fn get(&self, key: &str) -> Option<&CellResult> {
        self.cells.iter().find(|c| c.key == key)
    }

    /// One-line run summary for CLI output.
    pub fn summary_line(&self) -> String {
        let ooms = self.cells.iter().filter(|c| c.summary.oom).count();
        format!(
            "{} cells in {:.2}s on {} worker{} ({} OOM)",
            self.cells.len(),
            self.wall_seconds,
            self.jobs,
            if self.jobs == 1 { "" } else { "s" },
            ooms
        )
    }
}

#[cfg(test)]
mod tests {
    use crate::alloc::AllocatorConfig;
    use crate::policy::EmptyCachePolicy;
    use crate::strategies::StrategyConfig;
    use crate::sweep::{SweepGrid, SweepRunner};

    #[test]
    fn strategy_rows_pair_policies_per_block() {
        let cells = SweepGrid::new()
            .strategies([
                ("None", StrategyConfig::none()),
                ("ZeRO-3", StrategyConfig::zero3()),
            ])
            .policies([EmptyCachePolicy::Never, EmptyCachePolicy::AfterBoth])
            .steps(1)
            .build()
            .unwrap();
        let report = SweepRunner::new(2).run(cells);
        let blocks = report.strategy_rows();
        assert_eq!(blocks.len(), 1);
        let (fw, model, rows) = &blocks[0];
        assert_eq!(fw, "DeepSpeed-Chat");
        assert_eq!(model, "OPT");
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].strategy, "None");
        // The paired halves are distinct runs: empty_cache fired only in
        // the after_both half.
        assert_eq!(rows[0].original.empty_cache_calls, 0);
        assert!(rows[0].with_empty_cache.empty_cache_calls > 0);
    }

    #[test]
    fn allocator_axis_gets_its_own_rows() {
        let cells = SweepGrid::new()
            .allocator_configs([
                ("default", AllocatorConfig::default()),
                (
                    "expandable",
                    AllocatorConfig {
                        expandable_segments: true,
                        ..AllocatorConfig::default()
                    },
                ),
            ])
            .steps(1)
            .build()
            .unwrap();
        let report = SweepRunner::new(2).run(cells);
        let blocks = report.strategy_rows();
        assert_eq!(blocks.len(), 1);
        let rows = &blocks[0].2;
        assert_eq!(rows.len(), 2, "allocator variants must not collapse");
        assert_eq!(rows[0].strategy, "None");
        assert_eq!(rows[1].strategy, "None [expandable]");
    }

    #[test]
    fn sharing_axis_gets_its_own_rows() {
        use crate::rlhf::program::Sharing;
        let cells = SweepGrid::new()
            .sharings([Sharing::Separate, Sharing::Hydra])
            .steps(1)
            .build()
            .unwrap();
        let report = SweepRunner::new(2).run(cells);
        let rows = &report.strategy_rows()[0].2;
        assert_eq!(rows.len(), 2, "sharing variants must not collapse");
        assert_eq!(rows[0].strategy, "None");
        assert_eq!(rows[1].strategy, "None [hydra]");
    }

    #[test]
    fn table_and_jsonl_cover_every_cell() {
        let cells = SweepGrid::new().steps(1).build().unwrap();
        let report = SweepRunner::new(1).run(cells);
        assert_eq!(report.to_table().rows.len(), report.cells.len());
        // Schema header + one line per cell.
        assert_eq!(report.jsonl().lines().count(), report.cells.len() + 1);
        assert!(report.jsonl().starts_with("{\"schema\":\"rlhf-mem-sweep-v1\"}"));
        assert!(report.get("DeepSpeed-Chat/OPT/None/full/never").is_some());
        assert!(report.summary_line().contains("1 cell"));
    }
}
