//! Worker-pool execution of a sweep.
//!
//! Each worker thread pulls cell indices off a shared atomic counter and
//! runs the whole pipeline for that cell — trace build, its *own*
//! [`crate::alloc::CachingAllocator`] and [`MemoryProfiler`] — so there is
//! no shared mutable state between cells and the per-cell numbers are
//! bit-identical whatever `jobs` is. Only the optional JSON-lines stream
//! and the result slots sit behind mutexes.

use super::grid::SweepCell;
use super::report::SweepReport;
use crate::experiment::{run_scenario_with, ExperimentResult};
use crate::profiler::{MemoryProfiler, ProfileSummary};
use crate::util::json::Json;
use std::io::Write;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// The outcome of one cell: its identity labels plus the profile summary
/// (and, when [`SweepRunner::capture_profiles`] is on, the full profiler
/// with timeline and per-phase peaks).
#[derive(Debug)]
pub struct CellResult {
    /// Position of the cell in the input list (stable across `jobs`).
    pub index: usize,
    pub key: String,
    pub framework: String,
    pub model: String,
    pub strategy: String,
    pub mode: &'static str,
    pub policy: &'static str,
    /// RLHF algorithm name of the cell ("ppo" unless the grid's
    /// algorithm axis set one).
    pub algo: &'static str,
    /// Model-sharing placement name of the cell ("separate" unless the
    /// grid's sharing axis set one).
    pub sharing: &'static str,
    /// Allocator-config label of the cell ("default" unless the grid's
    /// allocator axis set one).
    pub alloc: String,
    pub seed: u64,
    pub summary: ProfileSummary,
    pub profiler: Option<MemoryProfiler>,
}

impl CellResult {
    /// The cell's JSON object (a pure function of the summary, so the
    /// line is byte-identical regardless of worker count or scheduling).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("index", Json::from(self.index)),
            ("key", Json::str(self.key.clone())),
            ("framework", Json::str(self.framework.clone())),
            ("model", Json::str(self.model.clone())),
            ("strategy", Json::str(self.strategy.clone())),
            ("mode", Json::str(self.mode)),
            ("policy", Json::str(self.policy)),
            ("algo", Json::str(self.algo)),
            ("sharing", Json::str(self.sharing)),
            ("alloc", Json::str(self.alloc.clone())),
            ("seed", Json::from(self.seed)),
            ("reserved", Json::from(self.summary.peak_reserved)),
            ("frag", Json::from(self.summary.frag)),
            ("allocated", Json::from(self.summary.peak_allocated)),
            ("frag_at_peak", Json::from(self.summary.frag_at_peak)),
            ("peak_phase", Json::str(self.summary.peak_phase.name())),
            ("empty_cache_calls", Json::from(self.summary.empty_cache_calls)),
            ("cuda_mallocs", Json::from(self.summary.cuda_mallocs)),
            ("total_time_us", Json::from(self.summary.total_time_us)),
            ("oom", Json::from(self.summary.oom)),
        ])
    }

    /// One JSON-lines record (no trailing newline).
    pub fn jsonl_line(&self) -> String {
        self.to_json().to_string()
    }
}

/// Shards sweep cells across a pool of `jobs` worker threads.
pub struct SweepRunner {
    jobs: usize,
    capture_profiles: bool,
    stream: Option<Box<dyn Write + Send>>,
}

impl SweepRunner {
    /// A runner with `jobs` worker threads (clamped to ≥ 1).
    pub fn new(jobs: usize) -> SweepRunner {
        SweepRunner {
            jobs: jobs.max(1),
            capture_profiles: false,
            stream: None,
        }
    }

    /// Number of workers to default to on this machine.
    pub fn default_jobs() -> usize {
        crate::util::cli::default_jobs()
    }

    /// Keep each cell's full [`MemoryProfiler`] (timeline, phase peaks,
    /// frag samples) in its [`CellResult`]. Off by default — summaries are
    /// two orders of magnitude smaller.
    pub fn capture_profiles(mut self, on: bool) -> Self {
        self.capture_profiles = on;
        self
    }

    /// Stream each cell's JSON line to `w` as it completes. Lines appear
    /// in *completion* order (nondeterministic with `jobs > 1`); use
    /// [`SweepReport::jsonl`] for the deterministic, index-ordered dump.
    pub fn stream_jsonl(mut self, w: Box<dyn Write + Send>) -> Self {
        self.stream = Some(w);
        self
    }

    /// Run every cell and aggregate the report (cells in input order).
    pub fn run(self, cells: Vec<SweepCell>) -> SweepReport {
        let started = Instant::now();
        let n = cells.len();
        let jobs = self.jobs.min(n.max(1));
        let capture = self.capture_profiles;
        let stream = self.stream.map(Mutex::new);

        let mut slots: Vec<Option<CellResult>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        let slots = Mutex::new(slots);
        let next = AtomicUsize::new(0);

        let work = |cursor: &AtomicUsize| loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let result = run_cell(i, &cells[i], capture);
            if let Some(w) = &stream {
                let mut w = w.lock().unwrap();
                let _ = writeln!(w, "{}", result.jsonl_line());
            }
            slots.lock().unwrap()[i] = Some(result);
        };

        if jobs <= 1 {
            work(&next);
        } else {
            std::thread::scope(|s| {
                for _ in 0..jobs {
                    s.spawn(|| work(&next));
                }
            });
        }

        let cells_out: Vec<CellResult> = slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("every cell index was claimed by a worker"))
            .collect();
        SweepReport {
            cells: cells_out,
            wall_seconds: started.elapsed().as_secs_f64(),
            jobs,
        }
    }
}

fn run_cell(index: usize, cell: &SweepCell, capture: bool) -> CellResult {
    let ExperimentResult {
        summary, profiler, ..
    } = run_scenario_with(&cell.scenario, cell.capacity, &cell.alloc_cfg);
    CellResult {
        index,
        key: cell.key.clone(),
        framework: cell.framework.clone(),
        model: cell.model.clone(),
        strategy: cell.strategy.clone(),
        mode: cell.mode.name(),
        policy: cell.policy.name(),
        algo: cell.algo.name(),
        sharing: cell.sharing.name(),
        alloc: cell.alloc_label.clone(),
        seed: cell.scenario.seed,
        summary,
        profiler: if capture { Some(profiler) } else { None },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::EmptyCachePolicy;
    use crate::strategies::StrategyConfig;
    use crate::sweep::SweepGrid;

    fn tiny_cells() -> Vec<SweepCell> {
        SweepGrid::new()
            .strategies([
                ("None", StrategyConfig::none()),
                ("ZeRO-3", StrategyConfig::zero3()),
            ])
            .policies([EmptyCachePolicy::Never, EmptyCachePolicy::AfterBoth])
            .steps(1)
            .build()
            .unwrap()
    }

    #[test]
    fn serial_and_parallel_agree_byte_for_byte() {
        let cells = tiny_cells();
        let serial = SweepRunner::new(1).run(cells.clone());
        let parallel = SweepRunner::new(4).run(cells);
        assert_eq!(serial.jsonl(), parallel.jsonl());
        assert_eq!(serial.cells.len(), 4);
        // Results come back in input order regardless of scheduling.
        for (i, c) in parallel.cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
    }

    #[test]
    fn capture_profiles_keeps_timelines() {
        let mut cells = tiny_cells();
        cells.truncate(1);
        let report = SweepRunner::new(1).capture_profiles(true).run(cells.clone());
        let prof = report.cells[0].profiler.as_ref().expect("profiler kept");
        assert!(prof.timeline.points().len() > 50);
        let report = SweepRunner::new(1).run(cells);
        assert!(report.cells[0].profiler.is_none());
    }

    #[test]
    fn stream_receives_one_line_per_cell() {
        use std::sync::Arc;
        #[derive(Clone)]
        struct Shared(Arc<Mutex<Vec<u8>>>);
        impl Write for Shared {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let buf = Shared(Arc::new(Mutex::new(Vec::new())));
        let report = SweepRunner::new(2)
            .stream_jsonl(Box::new(buf.clone()))
            .run(tiny_cells());
        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        assert_eq!(text.lines().count(), report.cells.len());
        assert!(text.lines().all(|l| l.starts_with('{')));
    }
}
