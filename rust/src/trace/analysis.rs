//! Pure-trace analysis: live-byte accounting per tag, peak composition —
//! the debugging lens for calibrating the phase generators against the
//! paper's numbers (no allocator involved; this is ideal residency).

use super::op::{PhaseKind, Tag, Trace, TraceOp};
use std::collections::HashMap;

/// Composition of live bytes at the moment total residency peaked.
#[derive(Debug, Clone)]
pub struct PeakComposition {
    pub total: u64,
    pub phase: PhaseKind,
    pub by_tag: Vec<(Tag, u64)>,
}

/// Walk the trace tracking ideal (un-fragmented) residency.
pub fn peak_composition(trace: &Trace) -> PeakComposition {
    let mut live: HashMap<u64, (u64, Tag)> = HashMap::new();
    let mut by_tag: HashMap<Tag, u64> = HashMap::new();
    let mut total = 0u64;
    let mut phase = PhaseKind::Init;
    let mut best = PeakComposition {
        total: 0,
        phase,
        by_tag: vec![],
    };
    for op in &trace.ops {
        match op {
            TraceOp::Alloc { handle, bytes, tag } => {
                live.insert(handle.0, (*bytes, *tag));
                *by_tag.entry(*tag).or_default() += bytes;
                total += bytes;
                if total > best.total {
                    best.total = total;
                    best.phase = phase;
                    let mut v: Vec<(Tag, u64)> =
                        by_tag.iter().map(|(t, b)| (*t, *b)).collect();
                    v.sort_by_key(|(_, b)| std::cmp::Reverse(*b));
                    best.by_tag = v;
                }
            }
            TraceOp::Free { handle } => {
                let (bytes, tag) = live.remove(&handle.0).expect("free of dead handle");
                *by_tag.get_mut(&tag).unwrap() -= bytes;
                total -= bytes;
            }
            TraceOp::Phase(p) => phase = *p,
            _ => {}
        }
    }
    best
}

/// Per-phase ideal peak residency.
pub fn phase_peaks(trace: &Trace) -> Vec<(PhaseKind, u64)> {
    let mut live: HashMap<u64, u64> = HashMap::new();
    let mut total = 0u64;
    let mut phase = PhaseKind::Init;
    let mut peaks: HashMap<PhaseKind, u64> = HashMap::new();
    for op in &trace.ops {
        match op {
            TraceOp::Alloc { handle, bytes, .. } => {
                live.insert(handle.0, *bytes);
                total += bytes;
                let e = peaks.entry(phase).or_default();
                *e = (*e).max(total);
            }
            TraceOp::Free { handle } => {
                total -= live.remove(&handle.0).expect("dead handle");
            }
            TraceOp::Phase(p) => phase = *p,
            _ => {}
        }
    }
    let mut v: Vec<(PhaseKind, u64)> = peaks.into_iter().collect();
    v.sort_by_key(|(p, _)| p.tag());
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceBuilder;

    #[test]
    fn composition_finds_peak() {
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::Generation);
        let h1 = b.alloc(100, Tag::Param);
        b.phase(PhaseKind::TrainActor);
        let h2 = b.alloc(300, Tag::Grad);
        b.free(h2);
        b.free(h1);
        let trace = b.finish();
        let c = peak_composition(&trace);
        assert_eq!(c.total, 400);
        assert_eq!(c.phase, PhaseKind::TrainActor);
        assert_eq!(c.by_tag[0], (Tag::Grad, 300));
        assert_eq!(c.by_tag[1], (Tag::Param, 100));
    }

    #[test]
    fn phase_peaks_per_phase() {
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::Generation);
        b.transient([500], Tag::KvCache);
        b.phase(PhaseKind::TrainActor);
        b.transient([200], Tag::Grad);
        let peaks = phase_peaks(&b.finish());
        let gen = peaks.iter().find(|(p, _)| *p == PhaseKind::Generation).unwrap();
        let tr = peaks.iter().find(|(p, _)| *p == PhaseKind::TrainActor).unwrap();
        assert_eq!(gen.1, 500);
        assert_eq!(tr.1, 200);
    }
}
