//! Pure-trace analysis: live-byte accounting per tag, peak composition —
//! the debugging lens for calibrating the phase generators against the
//! paper's numbers (no allocator involved; this is ideal residency) —
//! plus [`check_invariants`], the structural checker the phase-program
//! property tests run over the full algo × strategy × mode grid.
//!
//! These dynamic invariants have static counterparts in [`crate::lint`]:
//! the dataflow pass (`RLHF001`–`RLHF006`) proves the def-use discipline
//! a clean trace exhibits *before* any trace exists, and
//! [`crate::lint::bounds`] brackets [`phase_peaks`] with intervals whose
//! soundness the `lint_soundness` integration test pins against this
//! module's accounting.

use super::op::{PhaseKind, Tag, Trace, TraceOp};
use std::collections::HashMap;

/// Structural invariants every compiled phase program's emission must
/// uphold:
///
/// 1. **Handle discipline** — every id allocates once (never reused, even
///    after a free), frees at most once, never frees before allocating,
///    and no zero-byte allocs.
/// 2. **Lifetime closure** — every alloc id is freed exactly once *or*
///    still live at the final `StepEnd` (persistent engine state); no
///    allocs/frees trail the final step boundary, where they would dodge
///    that accounting.
/// 3. **Phase-mark sequence** — the trace's `Phase` marks are exactly
///    `Init` followed by `expected_step_phases` repeated once per
///    `StepEnd`: only phases of roles this GPU hosts ever appear (the
///    compiled program filtered the rest out), in program order. Pass
///    [`crate::rlhf::program::PhaseProgram::step_phases`] of the
///    scenario's compiled program.
pub fn check_invariants(
    trace: &Trace,
    expected_step_phases: &[PhaseKind],
) -> Result<(), String> {
    use std::collections::HashSet;
    let mut live: HashSet<u64> = HashSet::new();
    let mut freed: HashSet<u64> = HashSet::new();
    let mut marks: Vec<PhaseKind> = Vec::new();
    let mut steps = 0u64;
    let mut last_step_end: Option<usize> = None;
    for (i, op) in trace.ops.iter().enumerate() {
        match op {
            TraceOp::Alloc { handle, bytes, .. } => {
                if *bytes == 0 {
                    return Err(format!("op {i}: zero-byte alloc"));
                }
                if live.contains(&handle.0) || freed.contains(&handle.0) {
                    return Err(format!("op {i}: handle {} reused", handle.0));
                }
                live.insert(handle.0);
            }
            TraceOp::Free { handle } => {
                if !live.remove(&handle.0) {
                    return Err(format!("op {i}: free of dead handle {}", handle.0));
                }
                freed.insert(handle.0);
            }
            TraceOp::Phase(p) => marks.push(*p),
            TraceOp::StepEnd { step } => {
                steps += 1;
                if *step != steps {
                    return Err(format!(
                        "op {i}: StepEnd {} out of order (expected {steps})",
                        step
                    ));
                }
                last_step_end = Some(i);
            }
            _ => {}
        }
    }
    match last_step_end {
        None => return Err("trace has no StepEnd".to_string()),
        Some(i) => {
            if trace.ops[i + 1..]
                .iter()
                .any(|op| matches!(op, TraceOp::Alloc { .. } | TraceOp::Free { .. }))
            {
                return Err("alloc/free after the final StepEnd".to_string());
            }
        }
    }
    let mut want = vec![PhaseKind::Init];
    for _ in 0..steps {
        want.extend_from_slice(expected_step_phases);
    }
    if marks != want {
        return Err(format!(
            "phase-mark sequence {:?} != program-expected {:?}",
            marks, want
        ));
    }
    Ok(())
}

/// Composition of live bytes at the moment total residency peaked.
#[derive(Debug, Clone)]
pub struct PeakComposition {
    pub total: u64,
    pub phase: PhaseKind,
    pub by_tag: Vec<(Tag, u64)>,
}

/// Walk the trace tracking ideal (un-fragmented) residency.
pub fn peak_composition(trace: &Trace) -> PeakComposition {
    let mut live: HashMap<u64, (u64, Tag)> = HashMap::new();
    let mut by_tag: HashMap<Tag, u64> = HashMap::new();
    let mut total = 0u64;
    let mut phase = PhaseKind::Init;
    let mut best = PeakComposition {
        total: 0,
        phase,
        by_tag: vec![],
    };
    for op in &trace.ops {
        match op {
            TraceOp::Alloc { handle, bytes, tag } => {
                live.insert(handle.0, (*bytes, *tag));
                *by_tag.entry(*tag).or_default() += bytes;
                total += bytes;
                if total > best.total {
                    best.total = total;
                    best.phase = phase;
                    let mut v: Vec<(Tag, u64)> =
                        by_tag.iter().map(|(t, b)| (*t, *b)).collect();
                    v.sort_by_key(|(_, b)| std::cmp::Reverse(*b));
                    best.by_tag = v;
                }
            }
            TraceOp::Free { handle } => {
                let (bytes, tag) = live.remove(&handle.0).expect("free of dead handle");
                *by_tag.get_mut(&tag).unwrap() -= bytes;
                total -= bytes;
            }
            TraceOp::Phase(p) => phase = *p,
            _ => {}
        }
    }
    best
}

/// Per-phase ideal peak residency.
pub fn phase_peaks(trace: &Trace) -> Vec<(PhaseKind, u64)> {
    let mut live: HashMap<u64, u64> = HashMap::new();
    let mut total = 0u64;
    let mut phase = PhaseKind::Init;
    let mut peaks: HashMap<PhaseKind, u64> = HashMap::new();
    for op in &trace.ops {
        match op {
            TraceOp::Alloc { handle, bytes, .. } => {
                live.insert(handle.0, *bytes);
                total += bytes;
                let e = peaks.entry(phase).or_default();
                *e = (*e).max(total);
            }
            TraceOp::Free { handle } => {
                total -= live.remove(&handle.0).expect("dead handle");
            }
            TraceOp::Phase(p) => phase = *p,
            _ => {}
        }
    }
    let mut v: Vec<(PhaseKind, u64)> = peaks.into_iter().collect();
    v.sort_by_key(|(p, _)| p.tag());
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceBuilder;

    #[test]
    fn composition_finds_peak() {
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::Generation);
        let h1 = b.alloc(100, Tag::Param);
        b.phase(PhaseKind::TrainActor);
        let h2 = b.alloc(300, Tag::Grad);
        b.free(h2);
        b.free(h1);
        let trace = b.finish();
        let c = peak_composition(&trace);
        assert_eq!(c.total, 400);
        assert_eq!(c.phase, PhaseKind::TrainActor);
        assert_eq!(c.by_tag[0], (Tag::Grad, 300));
        assert_eq!(c.by_tag[1], (Tag::Param, 100));
    }

    #[test]
    fn invariant_checker_accepts_well_formed_traces() {
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::Init);
        let persistent = b.alloc(100, Tag::Param);
        let _ = persistent; // live at StepEnd — allowed.
        for step in 1..=2 {
            b.phase(PhaseKind::Generation);
            b.transient([50], Tag::KvCache);
            b.phase(PhaseKind::TrainActor);
            b.transient([70], Tag::Grad);
            b.step_end(step);
        }
        let t = b.finish();
        check_invariants(&t, &[PhaseKind::Generation, PhaseKind::TrainActor]).unwrap();
        // A different expected pipeline must be rejected.
        assert!(check_invariants(&t, &[PhaseKind::Generation]).is_err());
    }

    #[test]
    fn invariant_checker_rejects_malformed_traces() {
        use crate::trace::{TraceHandle, TraceOp};
        // Double free.
        let t = Trace {
            ops: vec![
                TraceOp::Phase(PhaseKind::Init),
                TraceOp::Alloc {
                    handle: TraceHandle(1),
                    bytes: 10,
                    tag: Tag::Param,
                },
                TraceOp::Free {
                    handle: TraceHandle(1),
                },
                TraceOp::Free {
                    handle: TraceHandle(1),
                },
                TraceOp::StepEnd { step: 1 },
            ],
        };
        assert!(check_invariants(&t, &[]).is_err());
        // Handle reuse after free.
        let t = Trace {
            ops: vec![
                TraceOp::Phase(PhaseKind::Init),
                TraceOp::Alloc {
                    handle: TraceHandle(1),
                    bytes: 10,
                    tag: Tag::Param,
                },
                TraceOp::Free {
                    handle: TraceHandle(1),
                },
                TraceOp::Alloc {
                    handle: TraceHandle(1),
                    bytes: 20,
                    tag: Tag::Grad,
                },
                TraceOp::StepEnd { step: 1 },
            ],
        };
        assert!(check_invariants(&t, &[]).is_err());
        // Alloc trailing the final StepEnd.
        let t = Trace {
            ops: vec![
                TraceOp::Phase(PhaseKind::Init),
                TraceOp::StepEnd { step: 1 },
                TraceOp::Alloc {
                    handle: TraceHandle(9),
                    bytes: 10,
                    tag: Tag::Workspace,
                },
            ],
        };
        assert!(check_invariants(&t, &[]).is_err());
        // Missing StepEnd entirely.
        let t = Trace {
            ops: vec![TraceOp::Phase(PhaseKind::Init)],
        };
        assert!(check_invariants(&t, &[]).is_err());
        // Out-of-order step numbering.
        let t = Trace {
            ops: vec![TraceOp::Phase(PhaseKind::Init), TraceOp::StepEnd { step: 2 }],
        };
        assert!(check_invariants(&t, &[]).is_err());
    }

    #[test]
    fn phase_peaks_per_phase() {
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::Generation);
        b.transient([500], Tag::KvCache);
        b.phase(PhaseKind::TrainActor);
        b.transient([200], Tag::Grad);
        let peaks = phase_peaks(&b.finish());
        let gen = peaks.iter().find(|(p, _)| *p == PhaseKind::Generation).unwrap();
        let tr = peaks.iter().find(|(p, _)| *p == PhaseKind::TrainActor).unwrap();
        assert_eq!(gen.1, 500);
        assert_eq!(tr.1, 200);
    }
}
