//! Trace builder: the API phase generators write against.

use super::op::{PhaseKind, Tag, Trace, TraceHandle, TraceOp};

/// Records an op stream with automatic handle management.
#[derive(Debug, Default)]
pub struct TraceBuilder {
    trace: Trace,
    next_handle: u64,
}

impl TraceBuilder {
    pub fn new() -> Self {
        TraceBuilder {
            trace: Trace::default(),
            next_handle: 1,
        }
    }

    pub fn alloc(&mut self, bytes: u64, tag: Tag) -> TraceHandle {
        assert!(bytes > 0, "alloc(0) in trace (tag {:?})", tag);
        let h = TraceHandle(self.next_handle);
        self.next_handle += 1;
        self.trace.ops.push(TraceOp::Alloc {
            handle: h,
            bytes,
            tag,
        });
        h
    }

    pub fn free(&mut self, h: TraceHandle) {
        self.trace.ops.push(TraceOp::Free { handle: h });
    }

    pub fn free_all(&mut self, hs: impl IntoIterator<Item = TraceHandle>) {
        for h in hs {
            self.free(h);
        }
    }

    pub fn phase(&mut self, kind: PhaseKind) {
        self.trace.ops.push(TraceOp::Phase(kind));
    }

    pub fn empty_cache(&mut self) {
        self.trace.ops.push(TraceOp::EmptyCache);
    }

    pub fn compute(&mut self, us: f64) {
        if us > 0.0 {
            self.trace.ops.push(TraceOp::Compute { us });
        }
    }

    pub fn step_end(&mut self, step: u64) {
        self.trace.ops.push(TraceOp::StepEnd { step });
    }

    /// Allocate a list of (bytes) with one tag; returns the handles.
    pub fn alloc_group(&mut self, sizes: impl IntoIterator<Item = u64>, tag: Tag) -> Vec<TraceHandle> {
        sizes.into_iter().map(|b| self.alloc(b, tag)).collect()
    }

    /// Transient scope: allocate the sizes, run nothing, free them in
    /// reverse order (LIFO, matching PyTorch temp-tensor lifetimes).
    pub fn transient(&mut self, sizes: impl IntoIterator<Item = u64>, tag: Tag) {
        let hs = self.alloc_group(sizes, tag);
        for h in hs.into_iter().rev() {
            self.free(h);
        }
    }

    pub fn finish(self) -> Trace {
        self.trace
    }

    pub fn ops_len(&self) -> usize {
        self.trace.ops.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_produces_balanced_trace() {
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::Generation);
        let p = b.alloc(1024, Tag::Param);
        b.transient([512, 2048], Tag::Activation);
        b.free(p);
        b.empty_cache();
        let t = b.finish();
        assert_eq!(t.check_balanced().unwrap(), vec![]);
        assert_eq!(t.len(), 8);
    }

    #[test]
    fn transient_is_lifo() {
        let mut b = TraceBuilder::new();
        b.transient([1, 2], Tag::Workspace);
        let t = b.finish();
        match (&t.ops[2], &t.ops[3]) {
            (TraceOp::Free { handle: h1 }, TraceOp::Free { handle: h2 }) => {
                assert!(h1.0 > h2.0, "LIFO free order");
            }
            _ => panic!("expected frees"),
        }
    }

    #[test]
    fn handles_unique() {
        let mut b = TraceBuilder::new();
        let h1 = b.alloc(1, Tag::Param);
        let h2 = b.alloc(1, Tag::Param);
        assert_ne!(h1, h2);
    }

    #[test]
    fn zero_compute_elided() {
        let mut b = TraceBuilder::new();
        b.compute(0.0);
        b.compute(5.0);
        assert_eq!(b.finish().len(), 1);
    }
}
