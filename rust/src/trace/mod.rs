//! Allocation-trace IR: ops, phases, builder, and replay. The RLHF phase
//! generators (rlhf/) emit these streams; strategies and framework profiles
//! only change which ops are emitted.

pub mod analysis;
pub mod builder;
pub mod op;
pub mod replay;

pub use builder::TraceBuilder;
pub use op::{PhaseKind, Tag, Trace, TraceHandle, TraceOp};
pub use replay::{replay, NullPhaseSink, PhaseSink, ReplayOom, ReplayResult};
