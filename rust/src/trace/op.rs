//! Allocation-trace IR: the op stream RLHF phase generators emit and the
//! allocator replays. Everything the memory study measures is a function of
//! these streams — strategies and framework profiles only change the ops.

/// Semantic label of an allocation (attribution + diagnostics; the
/// allocator itself is label-agnostic).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tag {
    /// Model weights (resident).
    Param,
    /// Gradient tensor.
    Grad,
    /// Optimizer state (Adam m/v/master).
    OptState,
    /// Transient forward activation.
    Activation,
    /// Activation saved for backward (resident until its backward).
    SavedActivation,
    /// KV-cache tensor.
    KvCache,
    /// Logits.
    Logits,
    /// Collective-communication buffer (ZeRO gather/scatter).
    CommBuffer,
    /// Host-transfer staging buffer (CPU offload).
    Staging,
    /// Generic workspace / temporary.
    Workspace,
    /// Stored experience batch (prompts, responses, logprobs, values...).
    Experience,
}

impl Tag {
    pub fn name(self) -> &'static str {
        match self {
            Tag::Param => "param",
            Tag::Grad => "grad",
            Tag::OptState => "opt_state",
            Tag::Activation => "activation",
            Tag::SavedActivation => "saved_activation",
            Tag::KvCache => "kv_cache",
            Tag::Logits => "logits",
            Tag::CommBuffer => "comm_buffer",
            Tag::Staging => "staging",
            Tag::Workspace => "workspace",
            Tag::Experience => "experience",
        }
    }
}

/// RLHF pipeline phase (the paper's task structure).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PhaseKind {
    /// Weight loading / engine setup.
    Init,
    /// Actor autoregressive generation (experience collection).
    Generation,
    /// Forward of the actor over the full sequences (old logprobs).
    InferActor,
    /// Forward of the frozen reference model (KL baseline).
    InferReference,
    /// Forward of the reward model (sequence return).
    InferReward,
    /// Forward of the critic (value estimates).
    InferCritic,
    /// Actor PPO update (fwd + bwd + step).
    TrainActor,
    /// Critic value-loss update (fwd + bwd + step).
    TrainCritic,
    /// Between steps.
    Idle,
}

impl PhaseKind {
    pub const ALL: [PhaseKind; 9] = [
        PhaseKind::Init,
        PhaseKind::Generation,
        PhaseKind::InferActor,
        PhaseKind::InferReference,
        PhaseKind::InferReward,
        PhaseKind::InferCritic,
        PhaseKind::TrainActor,
        PhaseKind::TrainCritic,
        PhaseKind::Idle,
    ];

    pub fn tag(self) -> u16 {
        PhaseKind::ALL.iter().position(|p| *p == self).unwrap() as u16
    }

    pub fn from_tag(tag: u16) -> PhaseKind {
        PhaseKind::ALL[tag as usize]
    }

    pub fn name(self) -> &'static str {
        match self {
            PhaseKind::Init => "init",
            PhaseKind::Generation => "generation",
            PhaseKind::InferActor => "infer_actor",
            PhaseKind::InferReference => "infer_reference",
            PhaseKind::InferReward => "infer_reward",
            PhaseKind::InferCritic => "infer_critic",
            PhaseKind::TrainActor => "train_actor",
            PhaseKind::TrainCritic => "train_critic",
            PhaseKind::Idle => "idle",
        }
    }

    /// Is this one of the paper's "inference phases"?
    pub fn is_inference(self) -> bool {
        matches!(
            self,
            PhaseKind::Generation
                | PhaseKind::InferActor
                | PhaseKind::InferReference
                | PhaseKind::InferReward
                | PhaseKind::InferCritic
        )
    }

    /// Is this one of the paper's "training phases"?
    pub fn is_training(self) -> bool {
        matches!(self, PhaseKind::TrainActor | PhaseKind::TrainCritic)
    }
}

/// Handle within a trace (maps to an allocator handle at replay).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceHandle(pub u64);

/// One trace operation.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceOp {
    Alloc {
        handle: TraceHandle,
        bytes: u64,
        tag: Tag,
    },
    Free {
        handle: TraceHandle,
    },
    /// The paper's mitigation point.
    EmptyCache,
    /// Phase transition.
    Phase(PhaseKind),
    /// Advance simulated compute time (kernel execution between allocs).
    Compute {
        us: f64,
    },
    /// One PPO step boundary (timeline x-axis marker).
    StepEnd {
        step: u64,
    },
}

/// A recorded allocation trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub ops: Vec<TraceOp>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.ops.len()
    }
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Order-sensitive digest of the full op stream — two traces share a
    /// fingerprint iff they are op-for-op identical (handles, sizes, tags,
    /// phase marks, compute times, step boundaries). The sim golden tests
    /// pin the PPO pipeline with this.
    pub fn fingerprint(&self) -> u64 {
        use std::hash::Hasher;
        let mut h = crate::util::fasthash::FastHasher::default();
        h.write_u64(self.ops.len() as u64);
        for op in &self.ops {
            match op {
                TraceOp::Alloc { handle, bytes, tag } => {
                    h.write_u64(1);
                    h.write_u64(handle.0);
                    h.write_u64(*bytes);
                    h.write(tag.name().as_bytes());
                }
                TraceOp::Free { handle } => {
                    h.write_u64(2);
                    h.write_u64(handle.0);
                }
                TraceOp::EmptyCache => h.write_u64(3),
                TraceOp::Phase(p) => {
                    h.write_u64(4);
                    h.write_u64(p.tag() as u64);
                }
                TraceOp::Compute { us } => {
                    h.write_u64(5);
                    h.write_u64(us.to_bits());
                }
                TraceOp::StepEnd { step } => {
                    h.write_u64(6);
                    h.write_u64(*step);
                }
            }
        }
        h.finish()
    }

    /// Sanity check: every Free refers to a previously allocated, not yet
    /// freed handle; returns the set of leaked (never freed) handles.
    pub fn check_balanced(&self) -> Result<Vec<TraceHandle>, String> {
        use std::collections::HashSet;
        let mut live: HashSet<u64> = HashSet::new();
        for (i, op) in self.ops.iter().enumerate() {
            match op {
                TraceOp::Alloc { handle, bytes, .. } => {
                    if *bytes == 0 {
                        return Err(format!("op {i}: zero-byte alloc"));
                    }
                    if !live.insert(handle.0) {
                        return Err(format!("op {i}: handle {} reallocated", handle.0));
                    }
                }
                TraceOp::Free { handle } => {
                    if !live.remove(&handle.0) {
                        return Err(format!("op {i}: free of dead handle {}", handle.0));
                    }
                }
                _ => {}
            }
        }
        let mut leaked: Vec<TraceHandle> = live.into_iter().map(TraceHandle).collect();
        leaked.sort_by_key(|h| h.0);
        Ok(leaked)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_tag_roundtrip() {
        for p in PhaseKind::ALL {
            assert_eq!(PhaseKind::from_tag(p.tag()), p);
        }
    }

    #[test]
    fn phase_classification() {
        assert!(PhaseKind::Generation.is_inference());
        assert!(PhaseKind::InferReward.is_inference());
        assert!(PhaseKind::TrainActor.is_training());
        assert!(!PhaseKind::TrainActor.is_inference());
        assert!(!PhaseKind::Init.is_inference());
        assert!(!PhaseKind::Idle.is_training());
    }

    #[test]
    fn balanced_trace_ok() {
        let t = Trace {
            ops: vec![
                TraceOp::Alloc {
                    handle: TraceHandle(1),
                    bytes: 100,
                    tag: Tag::Param,
                },
                TraceOp::Free {
                    handle: TraceHandle(1),
                },
            ],
        };
        assert_eq!(t.check_balanced().unwrap(), vec![]);
    }

    #[test]
    fn leak_detected() {
        let t = Trace {
            ops: vec![TraceOp::Alloc {
                handle: TraceHandle(7),
                bytes: 100,
                tag: Tag::Param,
            }],
        };
        assert_eq!(t.check_balanced().unwrap(), vec![TraceHandle(7)]);
    }

    #[test]
    fn double_free_rejected() {
        let t = Trace {
            ops: vec![
                TraceOp::Alloc {
                    handle: TraceHandle(1),
                    bytes: 100,
                    tag: Tag::Param,
                },
                TraceOp::Free {
                    handle: TraceHandle(1),
                },
                TraceOp::Free {
                    handle: TraceHandle(1),
                },
            ],
        };
        assert!(t.check_balanced().is_err());
    }

    #[test]
    fn fingerprint_distinguishes_op_streams() {
        let mk = |bytes: u64| Trace {
            ops: vec![
                TraceOp::Phase(PhaseKind::Generation),
                TraceOp::Alloc {
                    handle: TraceHandle(1),
                    bytes,
                    tag: Tag::KvCache,
                },
                TraceOp::Free {
                    handle: TraceHandle(1),
                },
                TraceOp::StepEnd { step: 1 },
            ],
        };
        assert_eq!(mk(100).fingerprint(), mk(100).fingerprint());
        assert_ne!(mk(100).fingerprint(), mk(101).fingerprint());
        // Op order matters.
        let mut reordered = mk(100);
        reordered.ops.swap(0, 3);
        assert_ne!(reordered.fingerprint(), mk(100).fingerprint());
    }

    #[test]
    fn handle_reuse_rejected() {
        let t = Trace {
            ops: vec![
                TraceOp::Alloc {
                    handle: TraceHandle(1),
                    bytes: 100,
                    tag: Tag::Param,
                },
                TraceOp::Alloc {
                    handle: TraceHandle(1),
                    bytes: 200,
                    tag: Tag::Grad,
                },
            ],
        };
        assert!(t.check_balanced().is_err());
    }
}
