//! Replay a recorded trace through a [`CachingAllocator`].
//!
//! Replay owns the event plumbing: it switches the allocator's internal
//! event log on, drains it after every op, and forwards each
//! `(event, snapshot)` pair to the [`PhaseSink`] — so a sink (usually the
//! profiler) sees the exact same stream the old shared-observer design
//! delivered, but without any `Rc<RefCell<…>>` aliasing. Everything
//! involved is `Send`, which is what lets the sweep engine run one replay
//! per worker thread.

use super::op::{PhaseKind, Trace, TraceOp};
use crate::alloc::{AllocError, AllocEvent, AllocId, CachingAllocator, StatSnapshot};
use crate::util::fasthash::FastMap;

/// Where/why a replay stopped early.
#[derive(Debug)]
pub struct ReplayOom {
    pub op_index: usize,
    pub phase: PhaseKind,
    pub step: u64,
    pub error: AllocError,
}

/// Replay outcome.
#[derive(Debug)]
pub struct ReplayResult {
    pub ops_executed: usize,
    /// Simulated compute time added by `Compute` ops, microseconds (the
    /// allocator separately accumulates its own latency).
    pub compute_us: f64,
    pub steps_completed: u64,
    pub oom: Option<ReplayOom>,
}

impl ReplayResult {
    pub fn ok(&self) -> bool {
        self.oom.is_none()
    }
}

/// Sink for replay observations: phase transitions (the profiler draws
/// Figure 1's phase bands from them), step boundaries, and the allocator's
/// event stream, which replay drains after every trace op.
pub trait PhaseSink {
    fn on_phase(&mut self, phase: PhaseKind, alloc: &CachingAllocator, compute_us: f64);
    fn on_step_end(&mut self, step: u64, alloc: &CachingAllocator, compute_us: f64) {
        let _ = (step, alloc, compute_us);
    }
    /// One allocator event with the stats snapshot taken when it fired.
    fn on_alloc_event(&mut self, event: &AllocEvent, state: &StatSnapshot) {
        let _ = (event, state);
    }
    /// The trace op about to execute. `AllocEvent`s carry no tag or trace
    /// handle, so a sink that needs attribution (the obs census) pairs the
    /// op seen here with the events that follow it.
    fn on_op(&mut self, op: &TraceOp) {
        let _ = op;
    }
    /// The op finished and its events have all been forwarded; the
    /// allocator is quiescent and may be introspected. This is where the
    /// peak recorder snapshots composition: reserved only rises inside an
    /// op's driver-growth path, so an op that set a new peak still holds
    /// `reserved() == peak_reserved` here.
    fn on_op_end(&mut self, alloc: &CachingAllocator) {
        let _ = alloc;
    }
}

/// No-op sink.
pub struct NullPhaseSink;
impl PhaseSink for NullPhaseSink {
    fn on_phase(&mut self, _: PhaseKind, _: &CachingAllocator, _: f64) {}
}

/// Replay `trace` into `alloc`. On OOM the replay stops (the paper's
/// frameworks crash there; we report instead) and the partial stats remain
/// in the allocator.
pub fn replay(trace: &Trace, alloc: &mut CachingAllocator, sink: &mut dyn PhaseSink) -> ReplayResult {
    let mut handles: FastMap<u64, AllocId> = FastMap::default();
    let mut compute_us = 0.0f64;
    let mut phase = PhaseKind::Init;
    let mut step = 0u64;
    let mut scratch: Vec<(AllocEvent, StatSnapshot)> = Vec::new();
    alloc.set_event_recording(true);

    for (i, op) in trace.ops.iter().enumerate() {
        sink.on_op(op);
        match op {
            TraceOp::Alloc { handle, bytes, .. } => match alloc.alloc(*bytes) {
                Ok(id) => {
                    handles.insert(handle.0, id);
                }
                Err(e) => {
                    // Forward the events of the failed op (OOM retries)
                    // before surfacing the error.
                    forward_events(alloc, sink, &mut scratch);
                    sink.on_op_end(alloc);
                    alloc.set_event_recording(false);
                    return ReplayResult {
                        ops_executed: i,
                        compute_us,
                        steps_completed: step,
                        oom: Some(ReplayOom {
                            op_index: i,
                            phase,
                            step,
                            error: e,
                        }),
                    };
                }
            },
            TraceOp::Free { handle } => {
                let id = handles
                    .remove(&handle.0)
                    .unwrap_or_else(|| panic!("replay: free of unknown handle {}", handle.0));
                alloc.free(id);
            }
            TraceOp::EmptyCache => {
                alloc.empty_cache();
            }
            TraceOp::Phase(kind) => {
                phase = *kind;
                alloc.set_phase(kind.tag());
                sink.on_phase(*kind, alloc, compute_us);
            }
            TraceOp::Compute { us } => {
                compute_us += us;
            }
            TraceOp::StepEnd { step: s } => {
                step = *s;
                sink.on_step_end(*s, alloc, compute_us);
            }
        }
        forward_events(alloc, sink, &mut scratch);
        sink.on_op_end(alloc);
    }
    // Leave the allocator as we found it: recording off, log empty —
    // otherwise an allocator reused after replay would buffer events
    // nobody drains.
    alloc.set_event_recording(false);
    ReplayResult {
        ops_executed: trace.ops.len(),
        compute_us,
        steps_completed: step,
        oom: None,
    }
}

/// Drain the allocator's buffered events into `scratch` and hand each one
/// to the sink (the scratch vec is reused to avoid per-op allocation).
fn forward_events(
    alloc: &mut CachingAllocator,
    sink: &mut dyn PhaseSink,
    scratch: &mut Vec<(AllocEvent, StatSnapshot)>,
) {
    alloc.drain_events_into(scratch);
    for (ev, snap) in scratch.drain(..) {
        sink.on_alloc_event(&ev, &snap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::builder::TraceBuilder;
    use crate::trace::op::Tag;
    use crate::util::bytes::{GIB, MIB};

    #[test]
    fn replay_drives_allocator() {
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::Generation);
        let h = b.alloc(5 * MIB, Tag::KvCache);
        b.transient([2 * MIB, 3 * MIB], Tag::Activation);
        b.free(h);
        b.empty_cache();
        b.step_end(1);
        let trace = b.finish();

        let mut alloc = CachingAllocator::with_default_config(GIB);
        let res = replay(&trace, &mut alloc, &mut NullPhaseSink);
        assert!(res.ok());
        assert_eq!(res.steps_completed, 1);
        assert_eq!(alloc.reserved(), 0, "empty_cache released everything");
        assert!(alloc.stats().peak_reserved >= 10 * MIB);
        alloc.validate().unwrap();
    }

    #[test]
    fn replay_reports_oom_with_context() {
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::TrainActor);
        b.alloc(2 * GIB, Tag::Grad);
        let trace = b.finish();
        let mut alloc = CachingAllocator::with_default_config(GIB);
        let res = replay(&trace, &mut alloc, &mut NullPhaseSink);
        let oom = res.oom.expect("must OOM");
        assert_eq!(oom.phase, PhaseKind::TrainActor);
        assert_eq!(oom.op_index, 1);
    }

    #[test]
    fn phase_sink_sees_transitions() {
        struct Collect(Vec<PhaseKind>);
        impl PhaseSink for Collect {
            fn on_phase(&mut self, p: PhaseKind, _: &CachingAllocator, _: f64) {
                self.0.push(p);
            }
        }
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::Generation);
        b.phase(PhaseKind::TrainActor);
        let trace = b.finish();
        let mut alloc = CachingAllocator::with_default_config(GIB);
        let mut sink = Collect(Vec::new());
        replay(&trace, &mut alloc, &mut sink);
        assert_eq!(sink.0, vec![PhaseKind::Generation, PhaseKind::TrainActor]);
    }

    #[test]
    fn alloc_events_forwarded_in_order() {
        struct Collect(Vec<AllocEvent>);
        impl PhaseSink for Collect {
            fn on_phase(&mut self, _: PhaseKind, _: &CachingAllocator, _: f64) {}
            fn on_alloc_event(&mut self, ev: &AllocEvent, _: &StatSnapshot) {
                self.0.push(ev.clone());
            }
        }
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::Generation);
        let h = b.alloc(5 * MIB, Tag::KvCache);
        b.free(h);
        b.empty_cache();
        let trace = b.finish();
        let mut alloc = CachingAllocator::with_default_config(GIB);
        let mut sink = Collect(Vec::new());
        replay(&trace, &mut alloc, &mut sink);
        // CudaMalloc + Alloc, then Free, then CudaFree + EmptyCache.
        assert!(matches!(sink.0[0], AllocEvent::CudaMalloc { .. }));
        assert!(matches!(sink.0[1], AllocEvent::Alloc { .. }));
        assert!(matches!(sink.0[2], AllocEvent::Free { .. }));
        assert!(matches!(sink.0.last(), Some(AllocEvent::EmptyCache { .. })));
    }

    #[test]
    fn compute_time_accumulates() {
        let mut b = TraceBuilder::new();
        b.compute(100.0);
        b.compute(50.0);
        let trace = b.finish();
        let mut alloc = CachingAllocator::with_default_config(GIB);
        let res = replay(&trace, &mut alloc, &mut NullPhaseSink);
        assert_eq!(res.compute_us, 150.0);
    }
}
