//! Replay a recorded trace through a [`CachingAllocator`].

use super::op::{PhaseKind, Trace, TraceOp};
use crate::alloc::{AllocError, AllocId, CachingAllocator};
use crate::util::fasthash::FastMap;

/// Where/why a replay stopped early.
#[derive(Debug)]
pub struct ReplayOom {
    pub op_index: usize,
    pub phase: PhaseKind,
    pub step: u64,
    pub error: AllocError,
}

/// Replay outcome.
#[derive(Debug)]
pub struct ReplayResult {
    pub ops_executed: usize,
    /// Simulated compute time added by `Compute` ops, microseconds (the
    /// allocator separately accumulates its own latency).
    pub compute_us: f64,
    pub steps_completed: u64,
    pub oom: Option<ReplayOom>,
}

impl ReplayResult {
    pub fn ok(&self) -> bool {
        self.oom.is_none()
    }
}

/// Sink for phase transitions during replay (the profiler implements this
/// to draw Figure 1's phase bands; tests use closures).
pub trait PhaseSink {
    fn on_phase(&mut self, phase: PhaseKind, alloc: &CachingAllocator, compute_us: f64);
    fn on_step_end(&mut self, step: u64, alloc: &CachingAllocator, compute_us: f64) {
        let _ = (step, alloc, compute_us);
    }
}

/// No-op sink.
pub struct NullPhaseSink;
impl PhaseSink for NullPhaseSink {
    fn on_phase(&mut self, _: PhaseKind, _: &CachingAllocator, _: f64) {}
}

/// Replay `trace` into `alloc`. On OOM the replay stops (the paper's
/// frameworks crash there; we report instead) and the partial stats remain
/// in the allocator.
pub fn replay(trace: &Trace, alloc: &mut CachingAllocator, sink: &mut dyn PhaseSink) -> ReplayResult {
    let mut handles: FastMap<u64, AllocId> = FastMap::default();
    let mut compute_us = 0.0f64;
    let mut phase = PhaseKind::Init;
    let mut step = 0u64;

    for (i, op) in trace.ops.iter().enumerate() {
        match op {
            TraceOp::Alloc { handle, bytes, .. } => match alloc.alloc(*bytes) {
                Ok(id) => {
                    handles.insert(handle.0, id);
                }
                Err(e) => {
                    return ReplayResult {
                        ops_executed: i,
                        compute_us,
                        steps_completed: step,
                        oom: Some(ReplayOom {
                            op_index: i,
                            phase,
                            step,
                            error: e,
                        }),
                    };
                }
            },
            TraceOp::Free { handle } => {
                let id = handles
                    .remove(&handle.0)
                    .unwrap_or_else(|| panic!("replay: free of unknown handle {}", handle.0));
                alloc.free(id);
            }
            TraceOp::EmptyCache => {
                alloc.empty_cache();
            }
            TraceOp::Phase(kind) => {
                phase = *kind;
                alloc.set_phase(kind.tag());
                sink.on_phase(*kind, alloc, compute_us);
            }
            TraceOp::Compute { us } => {
                compute_us += us;
            }
            TraceOp::StepEnd { step: s } => {
                step = *s;
                sink.on_step_end(*s, alloc, compute_us);
            }
        }
    }
    ReplayResult {
        ops_executed: trace.ops.len(),
        compute_us,
        steps_completed: step,
        oom: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::builder::TraceBuilder;
    use crate::trace::op::Tag;
    use crate::util::bytes::{GIB, MIB};

    #[test]
    fn replay_drives_allocator() {
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::Generation);
        let h = b.alloc(5 * MIB, Tag::KvCache);
        b.transient([2 * MIB, 3 * MIB], Tag::Activation);
        b.free(h);
        b.empty_cache();
        b.step_end(1);
        let trace = b.finish();

        let mut alloc = CachingAllocator::with_default_config(GIB);
        let res = replay(&trace, &mut alloc, &mut NullPhaseSink);
        assert!(res.ok());
        assert_eq!(res.steps_completed, 1);
        assert_eq!(alloc.reserved(), 0, "empty_cache released everything");
        assert!(alloc.stats().peak_reserved >= 10 * MIB);
        alloc.validate().unwrap();
    }

    #[test]
    fn replay_reports_oom_with_context() {
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::TrainActor);
        b.alloc(2 * GIB, Tag::Grad);
        let trace = b.finish();
        let mut alloc = CachingAllocator::with_default_config(GIB);
        let res = replay(&trace, &mut alloc, &mut NullPhaseSink);
        let oom = res.oom.expect("must OOM");
        assert_eq!(oom.phase, PhaseKind::TrainActor);
        assert_eq!(oom.op_index, 1);
    }

    #[test]
    fn phase_sink_sees_transitions() {
        struct Collect(Vec<PhaseKind>);
        impl PhaseSink for Collect {
            fn on_phase(&mut self, p: PhaseKind, _: &CachingAllocator, _: f64) {
                self.0.push(p);
            }
        }
        let mut b = TraceBuilder::new();
        b.phase(PhaseKind::Generation);
        b.phase(PhaseKind::TrainActor);
        let trace = b.finish();
        let mut alloc = CachingAllocator::with_default_config(GIB);
        let mut sink = Collect(Vec::new());
        replay(&trace, &mut alloc, &mut sink);
        assert_eq!(sink.0, vec![PhaseKind::Generation, PhaseKind::TrainActor]);
    }

    #[test]
    fn compute_time_accumulates() {
        let mut b = TraceBuilder::new();
        b.compute(100.0);
        b.compute(50.0);
        let trace = b.finish();
        let mut alloc = CachingAllocator::with_default_config(GIB);
        let res = replay(&trace, &mut alloc, &mut NullPhaseSink);
        assert_eq!(res.compute_us, 150.0);
    }
}
