//! Byte-size constants, rounding helpers and human-readable formatting.
//!
//! All memory quantities in the library are `u64` byte counts. The paper
//! reports GB figures that are really GiB (PyTorch's convention), so
//! [`fmt_gib`] is what the report layer uses.

pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * KIB;
pub const GIB: u64 = 1024 * MIB;

/// Round `n` up to a multiple of `align` (power-of-two not required).
#[inline]
pub fn round_up(n: u64, align: u64) -> u64 {
    debug_assert!(align > 0);
    n.div_ceil(align) * align
}

/// Round `n` down to a multiple of `align`.
#[inline]
pub fn round_down(n: u64, align: u64) -> u64 {
    debug_assert!(align > 0);
    (n / align) * align
}

/// Format as GiB with one decimal, matching the paper's tables ("18.8").
pub fn fmt_gib(bytes: u64) -> String {
    format!("{:.1}", bytes as f64 / GIB as f64)
}

/// Format as GiB, but render values under 0.05 GiB the way the paper does
/// ("< 0.1") so rendered tables are directly comparable.
pub fn fmt_gib_paper(bytes: u64) -> String {
    let g = bytes as f64 / GIB as f64;
    if g > 0.0 && g < 0.05 {
        "<0.1".to_string()
    } else {
        format!("{g:.1}")
    }
}

/// Human-readable adaptive formatting for logs ("1.50 GiB", "312.0 MiB").
pub fn fmt_bytes(bytes: u64) -> String {
    if bytes >= GIB {
        format!("{:.2} GiB", bytes as f64 / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.1} MiB", bytes as f64 / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.1} KiB", bytes as f64 / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

/// Parse "24GiB", "512MiB", "2048" (bytes), "1.5GiB" forms used by configs.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let s = s.trim();
    let lower = s.to_ascii_lowercase();
    let (num, mult) = if let Some(p) = lower.strip_suffix("gib") {
        (p, GIB)
    } else if let Some(p) = lower.strip_suffix("gb") {
        (p, GIB)
    } else if let Some(p) = lower.strip_suffix("mib") {
        (p, MIB)
    } else if let Some(p) = lower.strip_suffix("mb") {
        (p, MIB)
    } else if let Some(p) = lower.strip_suffix("kib") {
        (p, KIB)
    } else if let Some(p) = lower.strip_suffix("kb") {
        (p, KIB)
    } else if let Some(p) = lower.strip_suffix('b') {
        (p, 1)
    } else {
        (lower.as_str(), 1)
    };
    let num = num.trim();
    let val: f64 = num
        .parse()
        .map_err(|e| format!("bad byte size '{s}': {e}"))?;
    if val < 0.0 {
        return Err(format!("negative byte size '{s}'"));
    }
    Ok((val * mult as f64).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 512), 0);
        assert_eq!(round_up(1, 512), 512);
        assert_eq!(round_up(512, 512), 512);
        assert_eq!(round_up(513, 512), 1024);
        assert_eq!(round_up(3 * MIB + 1, 2 * MIB), 4 * MIB);
    }

    #[test]
    fn round_down_basics() {
        assert_eq!(round_down(1023, 512), 512);
        assert_eq!(round_down(512, 512), 512);
        assert_eq!(round_down(511, 512), 0);
    }

    #[test]
    fn gib_formatting() {
        assert_eq!(fmt_gib(18 * GIB + 820 * MIB), "18.8");
        assert_eq!(fmt_gib_paper(10 * MIB), "<0.1");
        assert_eq!(fmt_gib_paper(0), "0.0");
        assert_eq!(fmt_gib_paper(6 * GIB + 200 * MIB), "6.2");
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(parse_bytes("24GiB").unwrap(), 24 * GIB);
        assert_eq!(parse_bytes("24gb").unwrap(), 24 * GIB);
        assert_eq!(parse_bytes("1.5GiB").unwrap(), GIB + 512 * MIB);
        assert_eq!(parse_bytes("512 MiB").unwrap(), 512 * MIB);
        assert_eq!(parse_bytes("2048").unwrap(), 2048);
        assert_eq!(parse_bytes("100b").unwrap(), 100);
        assert!(parse_bytes("x").is_err());
        assert!(parse_bytes("-1gb").is_err());
    }

    #[test]
    fn fmt_bytes_adaptive() {
        assert_eq!(fmt_bytes(100), "100 B");
        assert_eq!(fmt_bytes(2 * KIB), "2.0 KiB");
        assert_eq!(fmt_bytes(3 * MIB + 512 * KIB), "3.5 MiB");
        assert_eq!(fmt_bytes(GIB + GIB / 2), "1.50 GiB");
    }
}
