//! Tiny command-line argument parser (no `clap` offline).
//!
//! Supports the subset the launcher needs: a subcommand, `--flag`,
//! `--key value` / `--key=value`, and positional arguments, with generated
//! usage text and typed accessors.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut it = raw.into_iter().peekable();
        let mut subcommand = None;
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        // First non-flag token is the subcommand.
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` if next token isn't a flag; else boolean.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            flags.insert(name.to_string(), v);
                        }
                        _ => {
                            flags.insert(name.to_string(), "true".to_string());
                        }
                    }
                }
            } else if subcommand.is_none() {
                subcommand = Some(tok);
            } else {
                positional.push(tok);
            }
        }
        Args {
            subcommand,
            flags,
            positional,
        }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// Boolean flag: present without value, or `--x=true/false`.
    pub fn bool_flag(&self, name: &str) -> bool {
        match self.flag(name) {
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => true,
            None => false,
        }
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name} expects an integer: {e}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        self.get_u64(name, default as u64).map(|v| v as usize)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name} expects a number: {e}")),
        }
    }
}

/// Split a comma-separated CLI list, trimming whitespace and dropping
/// empty entries — the shared helper behind every `--foo a,b,c` flag.
pub fn split_list(s: &str) -> impl Iterator<Item = &str> {
    s.split(',').map(str::trim).filter(|x| !x.is_empty())
}

/// Default worker count: every core the OS reports, one when it won't
/// say. The shared default behind every `--jobs` flag.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Default `--tolerance-gib` for the paper-comparison gates: the gate
/// trips when any compared cell deviates from the paper's bar chart by
/// more than this many GiB.
pub const DEFAULT_TOLERANCE_GIB: f64 = 2.0;

/// The flags every artifact-producing subcommand shares, parsed once.
///
/// Spellings are the crate-wide contract: `--jobs N`, `--seed N`,
/// `--jsonl FILE`, `--json FILE`, `--trace-out FILE`,
/// `--tolerance-gib T`. Commands read the parsed struct instead of
/// re-spelling the flag names, so a typo can't fork the CLI surface.
#[derive(Debug, Clone)]
pub struct CommonArgs {
    /// `--jobs N` — worker threads (default: all cores).
    pub jobs: usize,
    /// `--seed N` — base RNG seed (command-specific default).
    pub seed: u64,
    /// `--jsonl FILE` — deterministic JSON-lines artifact path.
    pub jsonl: Option<String>,
    /// `--json FILE` — single-document JSON artifact path.
    pub json: Option<String>,
    /// `--trace-out FILE` — Perfetto trace path.
    pub trace_out: Option<String>,
    /// `--tolerance-gib T` — paper-comparison gate width.
    pub tolerance_gib: f64,
}

impl CommonArgs {
    /// Parse the shared flags out of `args`. `seed_default` is the
    /// command's seed when `--seed` is absent.
    pub fn parse(args: &Args, seed_default: u64) -> Result<CommonArgs, String> {
        Ok(CommonArgs {
            jobs: args.get_usize("jobs", default_jobs())?,
            seed: args.get_u64("seed", seed_default)?,
            jsonl: args.flag("jsonl").map(String::from),
            json: args.flag("json").map(String::from),
            trace_out: args.flag("trace-out").map(String::from),
            tolerance_gib: args.get_f64("tolerance-gib", DEFAULT_TOLERANCE_GIB)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = args("table1 --framework deepspeed-chat --gpus=4 --assert");
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.flag("framework"), Some("deepspeed-chat"));
        assert_eq!(a.get_u64("gpus", 1).unwrap(), 4);
        assert!(a.bool_flag("assert"));
        assert!(!a.bool_flag("missing"));
    }

    #[test]
    fn positional_args() {
        let a = args("profile config.json extra");
        assert_eq!(a.subcommand.as_deref(), Some("profile"));
        assert_eq!(a.positional, vec!["config.json", "extra"]);
    }

    #[test]
    fn equals_and_separate_forms_match() {
        let a = args("x --k=v");
        let b = args("x --k v");
        assert_eq!(a.flag("k"), b.flag("k"));
    }

    #[test]
    fn boolean_flag_before_another_flag() {
        let a = args("x --verbose --n 3");
        assert!(a.bool_flag("verbose"));
        assert_eq!(a.get_u64("n", 0).unwrap(), 3);
    }

    #[test]
    fn typed_errors() {
        let a = args("x --n abc");
        assert!(a.get_u64("n", 0).is_err());
        assert!(a.get_f64("n", 0.0).is_err());
    }

    #[test]
    fn explicit_false() {
        let a = args("x --feature=false");
        assert!(!a.bool_flag("feature"));
    }

    #[test]
    fn split_list_trims_and_drops_empties() {
        let v: Vec<&str> = split_list(" a, b ,,c ").collect();
        assert_eq!(v, vec!["a", "b", "c"]);
        assert_eq!(split_list("").count(), 0);
    }

    #[test]
    fn common_args_defaults_and_overrides() {
        let a = args("serve");
        let c = CommonArgs::parse(&a, 0xC0FFEE).unwrap();
        assert_eq!(c.jobs, default_jobs());
        assert_eq!(c.seed, 0xC0FFEE);
        assert_eq!(c.jsonl, None);
        assert_eq!(c.tolerance_gib, DEFAULT_TOLERANCE_GIB);

        let a = args(
            "sweep --jobs 3 --seed 9 --jsonl out.jsonl --json out.json \
             --trace-out t.json --tolerance-gib 1.5",
        );
        let c = CommonArgs::parse(&a, 0x5EED).unwrap();
        assert_eq!(c.jobs, 3);
        assert_eq!(c.seed, 9);
        assert_eq!(c.jsonl.as_deref(), Some("out.jsonl"));
        assert_eq!(c.json.as_deref(), Some("out.json"));
        assert_eq!(c.trace_out.as_deref(), Some("t.json"));
        assert_eq!(c.tolerance_gib, 1.5);
    }

    #[test]
    fn common_args_reports_bad_values() {
        let a = args("sweep --jobs abc");
        assert!(CommonArgs::parse(&a, 0).is_err());
        let a = args("sweep --tolerance-gib wide");
        assert!(CommonArgs::parse(&a, 0).is_err());
    }
}
