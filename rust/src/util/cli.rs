//! Tiny command-line argument parser (no `clap` offline).
//!
//! Supports the subset the launcher needs: a subcommand, `--flag`,
//! `--key value` / `--key=value`, and positional arguments, with generated
//! usage text and typed accessors.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut it = raw.into_iter().peekable();
        let mut subcommand = None;
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        // First non-flag token is the subcommand.
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` if next token isn't a flag; else boolean.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            flags.insert(name.to_string(), v);
                        }
                        _ => {
                            flags.insert(name.to_string(), "true".to_string());
                        }
                    }
                }
            } else if subcommand.is_none() {
                subcommand = Some(tok);
            } else {
                positional.push(tok);
            }
        }
        Args {
            subcommand,
            flags,
            positional,
        }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// Boolean flag: present without value, or `--x=true/false`.
    pub fn bool_flag(&self, name: &str) -> bool {
        match self.flag(name) {
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => true,
            None => false,
        }
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name} expects an integer: {e}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        self.get_u64(name, default as u64).map(|v| v as usize)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name} expects a number: {e}")),
        }
    }
}

/// Split a comma-separated CLI list, trimming whitespace and dropping
/// empty entries — the shared helper behind every `--foo a,b,c` flag.
pub fn split_list(s: &str) -> impl Iterator<Item = &str> {
    s.split(',').map(str::trim).filter(|x| !x.is_empty())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = args("table1 --framework deepspeed-chat --gpus=4 --assert");
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.flag("framework"), Some("deepspeed-chat"));
        assert_eq!(a.get_u64("gpus", 1).unwrap(), 4);
        assert!(a.bool_flag("assert"));
        assert!(!a.bool_flag("missing"));
    }

    #[test]
    fn positional_args() {
        let a = args("profile config.json extra");
        assert_eq!(a.subcommand.as_deref(), Some("profile"));
        assert_eq!(a.positional, vec!["config.json", "extra"]);
    }

    #[test]
    fn equals_and_separate_forms_match() {
        let a = args("x --k=v");
        let b = args("x --k v");
        assert_eq!(a.flag("k"), b.flag("k"));
    }

    #[test]
    fn boolean_flag_before_another_flag() {
        let a = args("x --verbose --n 3");
        assert!(a.bool_flag("verbose"));
        assert_eq!(a.get_u64("n", 0).unwrap(), 3);
    }

    #[test]
    fn typed_errors() {
        let a = args("x --n abc");
        assert!(a.get_u64("n", 0).is_err());
        assert!(a.get_f64("n", 0.0).is_err());
    }

    #[test]
    fn explicit_false() {
        let a = args("x --feature=false");
        assert!(!a.bool_flag("feature"));
    }

    #[test]
    fn split_list_trims_and_drops_empties() {
        let v: Vec<&str> = split_list(" a, b ,,c ").collect();
        assert_eq!(v, vec!["a", "b", "c"]);
        assert_eq!(split_list("").count(), 0);
    }
}
