//! Fast non-cryptographic hasher for the allocator's hot-path maps
//! (handles and segment ids are sequential u64/u32 — SipHash is wasted
//! effort there; this multiplies by a 64-bit odd constant like FxHash).

use std::hash::{BuildHasherDefault, Hasher};

#[derive(Default)]
pub struct FastHasher {
    state: u64,
}

impl Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = (self.state.rotate_left(5) ^ b as u64).wrapping_mul(0x517C_C1B7_2722_0A95);
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.state = (self.state ^ v).wrapping_mul(0x517C_C1B7_2722_0A95);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.write_u64(v as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }
}

pub type FastBuild = BuildHasherDefault<FastHasher>;

/// HashMap with the fast hasher.
pub type FastMap<K, V> = std::collections::HashMap<K, V, FastBuild>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributes_sequential_keys() {
        let mut m: FastMap<u64, u64> = FastMap::default();
        for i in 0..10_000u64 {
            m.insert(i, i * 2);
        }
        assert_eq!(m.len(), 10_000);
        for i in (0..10_000u64).step_by(97) {
            assert_eq!(m[&i], i * 2);
        }
    }
}
