//! Minimal JSON codec (parser + writer) — the offline environment has no
//! `serde`/`serde_json`, so the library carries its own.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes and
//! `\uXXXX` including surrogate pairs, numbers, booleans, null). Object key
//! order is preserved (insertion order) so emitted manifests and reports are
//! stable and diffable.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object: insertion-ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    /// Convenience: `obj.get_str("name")` etc. with error messages.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }
    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| format!("key '{key}' is not a string"))
    }
    pub fn req_u64(&self, key: &str) -> Result<u64, String> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| format!("key '{key}' is not a u64"))
    }
    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| format!("key '{key}' is not a number"))
    }
    pub fn req_arr(&self, key: &str) -> Result<&[Json], String> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| format!("key '{key}' is not an array"))
    }

    /// Build an object from pairs (helper for writers).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Serialize with 2-space indentation. (Compact serialization is
    /// `Display`/`ToString`: `json.to_string()`.)
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, Some(2), 0);
        s
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s, None, 0);
        f.write_str(&s)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<BTreeMap<String, Json>> for Json {
    fn from(m: BTreeMap<String, Json>) -> Self {
        Json::Obj(m.into_iter().collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(n: f64, out: &mut String) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        // JSON has no NaN/Inf; emit null like most tolerant writers.
        out.push_str("null");
    }
}

fn write_json(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    let nl = |out: &mut String, d: usize| {
        if let Some(w) = indent {
            out.push('\n');
            out.push_str(&" ".repeat(w * d));
        }
    };
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => write_num(*n, out),
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                nl(out, depth + 1);
                write_json(item, out, indent, depth + 1);
            }
            nl(out, depth);
            out.push(']');
        }
        Json::Obj(kvs) => {
            if kvs.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in kvs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                nl(out, depth + 1);
                write_escaped(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_json(val, out, indent, depth + 1);
            }
            nl(out, depth);
            out.push('}');
        }
    }
}

/// Parse a JSON document. Returns the value and fails on trailing garbage.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> String {
        format!("json parse error at byte {}: {msg}", self.pos)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            kvs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let cp = 0x10000
                                        + ((hi - 0xD800) as u32) * 0x400
                                        + (lo - 0xDC00) as u32;
                                    char::from_u32(cp)
                                        .ok_or_else(|| self.err("bad surrogate pair"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(hi as u32)
                                    .ok_or_else(|| self.err("bad \\u escape"))?
                            };
                            s.push(c);
                            continue; // hex4 already advanced
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character (multi-byte safe).
                    let rest = &self.bytes[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += chunk.len();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, String> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u16::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| self.err(&format!("bad number '{text}': {e}")))
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first < 0xE0 {
        2
    } else if first < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\nb\t\"q\"Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\"Aé");
    }

    #[test]
    fn surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("'single'").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"opt-1.3b","layers":24,"ok":true,"xs":[1,2.5,null],"nested":{"k":"v"}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        if let Json::Obj(kvs) = &v {
            let keys: Vec<&str> = kvs.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, ["z", "a", "m"]);
        } else {
            panic!("not an object");
        }
    }

    #[test]
    fn integers_stay_integral() {
        let v = Json::Num(1234567.0);
        assert_eq!(v.to_string(), "1234567");
        let v = Json::Num(0.5);
        assert_eq!(v.to_string(), "0.5");
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 7, "s": "x", "f": 1.5}"#).unwrap();
        assert_eq!(v.req_u64("n").unwrap(), 7);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_f64("f").unwrap(), 1.5);
        assert!(v.req_u64("f").is_err());
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo wörld 中文\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld 中文");
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }
}
