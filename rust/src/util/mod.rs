//! In-repo substitutes for crates unavailable in the offline environment
//! (`rand`, `serde_json`, `clap`, plus small numeric helpers).

pub mod bytes;
pub mod fasthash;
pub mod cli;
pub mod json;
pub mod prng;
pub mod schema;
pub mod stats;
