//! Deterministic, seedable PRNG used everywhere randomness is needed.
//!
//! The offline environment has no `rand` crate, so we implement the
//! well-known SplitMix64 (for seeding) and xoshiro256++ (for the stream)
//! generators. Both are tiny, fast, and have published reference outputs we
//! test against. Every stochastic component in the library (synthetic
//! corpus, trace jitter, property tests) takes an explicit seed so that
//! experiments are exactly reproducible.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the library's workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `u64` in `[0, n)`. Uses Lemire's multiply-shift rejection
    /// method to avoid modulo bias.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)` (half-open).
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "range_usize: empty range {lo}..{hi}");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple, adequate
    /// for synthetic-data purposes).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: all-zero weights");
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public SplitMix64
        // reference implementation.
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        // Self-consistency: stable across runs and platforms.
        assert_eq!(v, {
            let mut sm2 = SplitMix64::new(1234567);
            (0..3).map(|_| sm2.next_u64()).collect::<Vec<_>>()
        });
        // And genuinely mixed (no short cycles / zeros).
        assert!(v[0] != v[1] && v[1] != v[2] && v[0] != 0);
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::seeded(7);
        for n in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(9);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniformity_coarse() {
        // 10 buckets, 100k draws: each bucket within 10% of expectation.
        let mut r = Rng::seeded(11);
        let mut buckets = [0u32; 10];
        for _ in 0..100_000 {
            buckets[(r.next_f64() * 10.0) as usize] += 1;
        }
        for b in buckets {
            assert!((9_000..11_000).contains(&b), "bucket {b}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::seeded(19);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0u32; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.6..3.4).contains(&ratio), "ratio {ratio}");
    }
}
