//! Versioned JSONL artifact headers. Every multi-line artifact the CLI
//! writes (sweep / planner / cluster / serve cells, telemetry footers)
//! starts with a header line `{"schema":"rlhf-mem-<kind>-v1"}` mirroring
//! `SURROGATE.json`'s scheme, so readers can refuse files written by an
//! incompatible binary instead of mis-parsing them. See DESIGN.md §18.

use super::json::{parse, Json};

/// Current artifact-format version, shared by every JSONL kind. Bump when
/// a line format changes incompatibly; readers reject other versions.
pub const VERSION: u32 = 1;

/// The full schema tag for an artifact kind, e.g. `rlhf-mem-sweep-v1`.
pub fn tag(kind: &str) -> String {
    format!("rlhf-mem-{kind}-v{VERSION}")
}

/// The header line (no trailing newline) that must open a `kind` artifact.
pub fn header_line(kind: &str) -> String {
    Json::obj(vec![("schema", Json::str(tag(kind)))]).to_string()
}

/// Validate that `text` (a whole JSONL artifact) opens with the versioned
/// header for `kind`. Returns an actionable error on any mismatch: missing
/// header, wrong kind, or wrong version.
pub fn check_jsonl(kind: &str, text: &str) -> Result<(), String> {
    let want = tag(kind);
    let first = text
        .lines()
        .next()
        .ok_or_else(|| format!("empty artifact: expected a '{want}' schema header line"))?;
    let parsed = parse(first)
        .map_err(|e| format!("artifact header is not JSON ({e}): {first}"))?;
    match parsed.get("schema").and_then(Json::as_str) {
        None => Err(format!(
            "artifact has no schema header (first line: {first}); \
             it predates the versioned-artifact scheme — regenerate it \
             with this binary (expected '{want}')"
        )),
        Some(got) if got == want => Ok(()),
        Some(got) => Err(format!(
            "artifact schema '{got}' does not match expected '{want}'; \
             regenerate the artifact with this binary or use a matching \
             rlhf-mem version"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_round_trips_through_check() {
        let text = format!("{}\n{{\"cell\":1}}\n", header_line("sweep"));
        assert!(check_jsonl("sweep", &text).is_ok());
    }

    #[test]
    fn wrong_kind_and_version_are_rejected_with_context() {
        let text = format!("{}\n", header_line("sweep"));
        let err = check_jsonl("serve", &text).unwrap_err();
        assert!(err.contains("rlhf-mem-sweep-v1"), "{err}");
        assert!(err.contains("rlhf-mem-serve-v1"), "{err}");

        let future = "{\"schema\":\"rlhf-mem-sweep-v9\"}\n";
        let err = check_jsonl("sweep", future).unwrap_err();
        assert!(err.contains("rlhf-mem-sweep-v9"), "{err}");
    }

    #[test]
    fn missing_header_and_empty_file_are_actionable() {
        let err = check_jsonl("sweep", "{\"cell\":1}\n").unwrap_err();
        assert!(err.contains("no schema header"), "{err}");
        let err = check_jsonl("sweep", "").unwrap_err();
        assert!(err.contains("empty artifact"), "{err}");
    }
}
