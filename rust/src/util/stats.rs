//! Small descriptive-statistics helpers shared by the bench harness and the
//! report layer (mean, median, percentiles, stddev, min/max).

/// Summary statistics over a sample of `f64` observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let mut xs = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: xs[0],
            p25: percentile_sorted(&xs, 0.25),
            median: percentile_sorted(&xs, 0.50),
            p75: percentile_sorted(&xs, 0.75),
            p95: percentile_sorted(&xs, 0.95),
            max: xs[n - 1],
        }
    }
}

/// Linear-interpolated percentile of a **sorted** sample; `q` in [0, 1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean of strictly positive values (used for speedup summaries).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .map(|x| {
            assert!(*x > 0.0, "geomean requires positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.25) - 2.5).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }
}
