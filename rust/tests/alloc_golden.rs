//! Event-log golden tests for the indexed allocator core.
//!
//! The PR that introduced the per-pool size-indexed free maps and the
//! fully-free-segment index promised **zero behavioral drift**: every
//! sweep, planner and cluster number must come out identical to the seed
//! scan-based allocator. These tests execute that promise against the
//! embedded pre-refactor oracle (`support/oracle.rs`, the seed allocator
//! verbatim): identical drained `(AllocEvent, StatSnapshot)` logs —
//! element for element, fingerprint for fingerprint, with bit-identical
//! simulated time — over random op streams, OOM-retry regimes, and real
//! PPO/GRPO/DPO traces, plus determinism of the fingerprint itself.

#[path = "support/oracle.rs"]
#[allow(dead_code)]
mod oracle;

use oracle::{assert_equivalent, assert_equivalent_on_trace};
use rlhf_mem::alloc::AllocatorConfig;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::program::Algo;
use rlhf_mem::rlhf::sim::{build_trace, SimScenario};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::util::bytes::{GIB, MIB};

#[test]
fn indexed_allocator_matches_oracle_on_random_streams() {
    // Roomy device: cache grows large, empty_cache has real work.
    assert_equivalent(&AllocatorConfig::default(), 4 * GIB, 0xA110C, 4_000, "roomy");
    // Tight device: the OOM-retry release cascade fires regularly.
    assert_equivalent(&AllocatorConfig::default(), 320 * MIB, 0xBEEF, 4_000, "tight");
    // Brutal device: frequent hard OOMs surface through both identically.
    assert_equivalent(&AllocatorConfig::default(), 96 * MIB, 0x0DD5, 2_000, "brutal");
}

// (The `max_split_size` × `expandable_segments` × `gc_threshold` knob
// grid is pinned against the oracle in `alloc_property.rs`, next to the
// knob grid's own invariant property tests.)

#[test]
fn indexed_allocator_matches_oracle_on_rlhf_traces() {
    // The Table-1 inner loop: a full PPO trace on the paper's RTX-3090
    // capacity, with the §3.3 mitigation enabled so EmptyCache trace ops
    // exercise the indexed release path mid-pipeline.
    let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::AfterBoth);
    scn.steps = 2;
    let trace = build_trace(&scn);
    assert_equivalent_on_trace(&AllocatorConfig::default(), 24 * GIB, &trace, "ds-opt/ppo");

    // A critic-free and a preference-only pipeline, ZeRO-3.
    for algo in [Algo::Grpo, Algo::Dpo] {
        let mut scn =
            SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::AfterInference);
        scn.steps = 1;
        scn.algo = algo;
        let trace = build_trace(&scn);
        let label = format!("ds-opt/{}", algo.name());
        assert_equivalent_on_trace(&AllocatorConfig::default(), 24 * GIB, &trace, &label);
    }

    // An undersized device: the trace OOMs; both allocators must OOM on
    // the same op with the same event history.
    let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
    scn.steps = 1;
    let trace = build_trace(&scn);
    assert_equivalent_on_trace(&AllocatorConfig::default(), 4 * GIB, &trace, "ds-opt/oom");

    // Allocator knobs over a real trace (the planner's candidate space).
    let knobbed = AllocatorConfig {
        expandable_segments: true,
        garbage_collection_threshold: Some(0.8),
        ..AllocatorConfig::default()
    };
    let mut scn = SimScenario::colossal_opt(StrategyConfig::zero3(), EmptyCachePolicy::AfterBoth);
    scn.steps = 1;
    let trace = build_trace(&scn);
    assert_equivalent_on_trace(&knobbed, 24 * GIB, &trace, "cc-opt/knobbed");
}

#[test]
fn equivalence_fingerprint_is_deterministic() {
    // Same config + seed ⇒ same shared fingerprint: the property that
    // lets `rlhf-mem bench` record event fingerprints as exact-match
    // counters in BENCH_<n>.json.
    let run = || assert_equivalent(&AllocatorConfig::default(), GIB, 0x5EED, 1_500, "fp");
    let a = run();
    let b = run();
    assert_eq!(a.fingerprint, b.fingerprint);
    assert_eq!(a.events, b.events);
    assert!(a.events > 0, "workload must emit events");
}
