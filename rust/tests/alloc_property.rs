//! Property tests over the caching allocator's knob space: seeded random
//! op streams (alloc / free / empty_cache across every size class) driven
//! through every `max_split_size` × `expandable_segments` ×
//! `garbage_collection_threshold` combination, with the O(everything)
//! `validate()` invariant check after **every** operation. This is the
//! contract ISSUE/DESIGN §6 demand of the knob emulations: they change
//! malloc/free *behaviour*, never break chain tiling, byte accounting, or
//! pool bookkeeping.

use rlhf_mem::alloc::{AllocId, AllocatorConfig, CachingAllocator};
use rlhf_mem::util::bytes::{GIB, KIB, MIB};
use rlhf_mem::util::prng::Rng;

#[path = "support/oracle.rs"]
#[allow(dead_code)]
mod oracle;

/// Every knob combination the planner searches, plus the untuned default.
fn knob_grid() -> Vec<AllocatorConfig> {
    let mut cfgs = Vec::new();
    for max_split in [None, Some(64 * MIB)] {
        for expandable in [false, true] {
            for gc in [None, Some(0.7)] {
                cfgs.push(AllocatorConfig {
                    max_split_size: max_split,
                    expandable_segments: expandable,
                    garbage_collection_threshold: gc,
                    ..AllocatorConfig::default()
                });
            }
        }
    }
    cfgs
}

/// One random op stream: mixed size classes (sub-KiB to tens of MiB),
/// biased toward allocation so the device fills, with periodic
/// `empty_cache` and a teardown to zero at the end.
fn drive(cfg: AllocatorConfig, seed: u64, steps: u64) {
    let label = cfg.knob_label();
    let mut a = CachingAllocator::new(GIB, cfg);
    let mut rng = Rng::seeded(seed);
    let mut live: Vec<AllocId> = Vec::new();
    for step in 0..steps {
        if live.is_empty() || rng.bernoulli(0.58) {
            let class = rng.gen_range(4);
            let sz = match class {
                0 => rng.gen_range(4 * KIB) + 1,
                1 => rng.gen_range(900 * KIB) + KIB,
                2 => rng.gen_range(8 * MIB) + MIB,
                _ => rng.gen_range(48 * MIB) + 10 * MIB,
            };
            if let Ok(h) = a.alloc(sz) {
                live.push(h);
            }
        } else {
            let i = rng.range_usize(0, live.len());
            a.free(live.swap_remove(i));
        }
        if step % 97 == 96 {
            a.empty_cache();
        }
        a.validate()
            .unwrap_or_else(|e| panic!("[{label}] seed {seed} step {step}: {e}"));
    }
    for h in live {
        a.free(h);
        a.validate()
            .unwrap_or_else(|e| panic!("[{label}] seed {seed} teardown: {e}"));
    }
    a.empty_cache();
    assert_eq!(a.reserved(), 0, "[{label}] cache must drain to zero");
    a.validate().unwrap();
}

#[test]
fn every_knob_combination_validates_after_every_op() {
    for cfg in knob_grid() {
        // Two seeds per combination: different interleavings exercise
        // different split/coalesce/grow/shrink/gc paths.
        for seed in [0xDEC0DE, 0xFACADE] {
            drive(cfg.clone(), seed, 700);
        }
    }
}

#[test]
fn knob_streams_are_deterministic() {
    // Same config + seed ⇒ identical end state — the property the
    // planner's jobs-independence rests on.
    for cfg in knob_grid() {
        let run = |cfg: AllocatorConfig| {
            let mut a = CachingAllocator::new(GIB, cfg);
            let mut rng = Rng::seeded(7);
            let mut live = Vec::new();
            for _ in 0..300 {
                if live.is_empty() || rng.bernoulli(0.6) {
                    if let Ok(h) = a.alloc(rng.gen_range(20 * MIB) + 1) {
                        live.push(h);
                    }
                } else {
                    let i = rng.range_usize(0, live.len());
                    a.free(live.swap_remove(i));
                }
            }
            let s = a.stats();
            (
                a.reserved(),
                a.allocated(),
                s.peak_reserved,
                s.max_frag_sample,
                s.num_cuda_mallocs,
                s.num_gc_passes,
            )
        };
        assert_eq!(run(cfg.clone()), run(cfg.clone()), "{}", cfg.knob_label());
    }
}

#[test]
fn knob_grid_matches_pre_refactor_oracle() {
    // Allocator-equivalence property: for every knob combination the
    // planner searches, the indexed allocator's drained
    // `(AllocEvent, StatSnapshot)` log must match the pre-refactor seed
    // oracle element for element (same fingerprint, same peak/frag
    // stats, bit-identical simulated time), and both must `validate()`.
    // The lockstep harness lives in `support/oracle.rs`.
    for cfg in knob_grid() {
        for seed in [0xDEC0DE, 0xFACADE] {
            let label = format!("oracle/{}/seed{seed:x}", cfg.knob_label());
            let eq = oracle::assert_equivalent(&cfg, GIB, seed, 1_200, &label);
            assert!(eq.events > 0, "[{label}] stream must emit events");
        }
    }
}

#[test]
fn gc_threshold_bounds_cached_garbage() {
    // With a gc threshold, reserved memory right after any alloc that
    // went to the driver should not wildly exceed threshold × capacity +
    // the live working set — spot-check via a fill/churn cycle.
    let cfg = AllocatorConfig {
        garbage_collection_threshold: Some(0.5),
        ..AllocatorConfig::default()
    };
    let mut a = CachingAllocator::new(GIB, cfg);
    let mut rng = Rng::seeded(99);
    let mut live: Vec<AllocId> = Vec::new();
    // Fill ~40% with medium blocks, then churn odd sizes.
    for _ in 0..20 {
        live.push(a.alloc(20 * MIB).unwrap());
    }
    for _ in 0..200 {
        if live.len() > 4 {
            let i = rng.range_usize(0, live.len());
            a.free(live.swap_remove(i));
        }
        if let Ok(h) = a.alloc(rng.gen_range(30 * MIB) + MIB) {
            live.push(h);
        }
        a.validate().unwrap();
    }
    let s = a.stats();
    assert!(s.num_gc_passes > 0, "churn past the threshold must gc");
    assert_eq!(s.gc_reclaimed % MIB, 0, "whole segments only");
}
