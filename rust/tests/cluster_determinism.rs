//! Cluster-path integration tests: the `--jobs` determinism contract for
//! the placement search, the paper's fused-placement claim (colocated
//! scorers beat a dedicated scorer GPU on total memory), and the example
//! budget round-tripping through `advise --cluster`.

use rlhf_mem::coordinator::schedule::run_plan;
use rlhf_mem::coordinator::PlacementPlan;
use rlhf_mem::experiment::RTX3090_HBM;
use rlhf_mem::planner::{plan_cluster, Budget};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::sim::SimScenario;
use rlhf_mem::strategies::StrategyConfig;

fn tiny_budget() -> Budget {
    let mut b = Budget::rtx3090_table1();
    b.steps = 1;
    b.strategies = Some(vec!["none".to_string(), "zero3".to_string()]);
    b.worlds = Some(vec![2]);
    b
}

#[test]
fn cluster_jobs1_and_jobs4_are_byte_identical() {
    let budget = tiny_budget();
    let serial = plan_cluster(&budget, 1).unwrap();
    let pooled = plan_cluster(&budget, 4).unwrap();
    assert_eq!(
        serial.jsonl(),
        pooled.jsonl(),
        "placement JSONL must not depend on the worker count"
    );
    assert_eq!(
        serial.to_json().to_string_pretty(),
        pooled.to_json().to_string_pretty(),
    );
    assert_eq!(
        serial.best().map(|o| o.candidate.key()),
        pooled.best().map(|o| o.candidate.key()),
    );
    assert_eq!(pooled.jobs, 4);
}

#[test]
fn cluster_reproduces_itself_across_runs() {
    let budget = tiny_budget();
    let a = plan_cluster(&budget, 3).unwrap();
    let b = plan_cluster(&budget, 3).unwrap();
    assert_eq!(a.jsonl(), b.jsonl());
}

#[test]
fn cluster_algo_axis_is_jobs_deterministic_and_lighter_without_critic() {
    let mut budget = tiny_budget();
    budget.strategies = Some(vec!["none".to_string()]);
    budget.algos = Some(vec!["ppo".to_string(), "grpo".to_string()]);
    let serial = plan_cluster(&budget, 1).unwrap();
    let pooled = plan_cluster(&budget, 4).unwrap();
    assert_eq!(serial.jsonl(), pooled.jsonl());
    // 3 plans × 1 strategy × 2 algos, keyed with the algo suffix.
    assert_eq!(serial.outcomes.len(), 6);
    let find = |key: &str| {
        serial
            .outcomes
            .iter()
            .find(|o| o.candidate.key() == key)
            .unwrap_or_else(|| panic!("missing {key}"))
    };
    let ppo = find("cluster/w2/colocated/None");
    let grpo = find("cluster/w2/colocated/None/grpo");
    assert!(
        grpo.run.max_peak_reserved() < ppo.run.max_peak_reserved(),
        "dropping the critic must lighten every colocated GPU"
    );
}

#[test]
fn cluster_sharing_axis_is_jobs_deterministic_and_lighter_shared() {
    let mut budget = tiny_budget();
    budget.strategies = Some(vec!["none".to_string()]);
    budget.sharings = Some(vec!["separate".to_string(), "lora".to_string()]);
    let serial = plan_cluster(&budget, 1).unwrap();
    let pooled = plan_cluster(&budget, 4).unwrap();
    assert_eq!(serial.jsonl(), pooled.jsonl());
    // 3 plans × 1 strategy × 2 sharings, keyed with the sharing suffix.
    assert_eq!(serial.outcomes.len(), 6);
    let find = |key: &str| {
        serial
            .outcomes
            .iter()
            .find(|o| o.candidate.key() == key)
            .unwrap_or_else(|| panic!("missing {key}"))
    };
    let separate = find("cluster/w2/colocated/None");
    let lora = find("cluster/w2/colocated/None/lora");
    assert!(
        lora.run.max_peak_reserved() < separate.run.max_peak_reserved(),
        "sharing the frozen backbones must lighten every colocated GPU"
    );
}

#[test]
fn fused_placement_beats_dedicated_gpu_total() {
    // The paper's (and Hydra's) fused-placement claim: colocating the
    // frozen reference + reward models with the training pair costs less
    // than the *total* HBM of a plan that parks them on a dedicated GPU —
    // the dedicated GPU duplicates activation/experience overheads that
    // fusion shares.
    let mut base = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
    base.steps = 1;
    base.world = 2;
    let colocated = run_plan(&PlacementPlan::colocated(2), &base, RTX3090_HBM).unwrap();
    let dedicated = run_plan(&PlacementPlan::dedicated(2).unwrap(), &base, RTX3090_HBM).unwrap();
    assert!(
        colocated.max_peak_reserved() < dedicated.total_peak_reserved(),
        "colocated per-GPU peak {} must undercut the dedicated plan's total {}",
        colocated.max_peak_reserved(),
        dedicated.total_peak_reserved()
    );
    // And the dedicated plan's point is low per-GPU pressure on the
    // training GPUs' side-car: its scorer GPU is the lightest GPU anywhere
    // in either plan.
    let lightest_dedicated = dedicated
        .gpus
        .iter()
        .map(|g| g.peak_reserved)
        .min()
        .unwrap();
    assert!(lightest_dedicated < colocated.max_peak_reserved());
}

#[test]
fn example_budget_round_trips_through_the_cluster_planner() {
    let mut budget =
        Budget::from_file("examples/budget_rtx3090.json").expect("example budget parses");
    // Narrow to keep the test fast; the full-space run is `advise --cluster`.
    budget.steps = 1;
    budget.strategies = Some(vec!["none".to_string()]);
    budget.worlds = Some(vec![2]);
    let report = plan_cluster(&budget, 2).unwrap();
    // 3 placement presets × the example file's two sharing placements.
    assert_eq!(report.outcomes.len(), 6, "3 plans x 2 sharings");
    let rec = report.recommended();
    assert!(
        !rec.is_empty(),
        "advise --cluster must return a non-empty ranked placement list"
    );
    // Frontier members are mutually non-dominated on (max GPU, step time).
    let frontier = report.frontier();
    assert!(!frontier.is_empty());
    for a in &frontier {
        for b in &frontier {
            if a.candidate.index == b.candidate.index {
                continue;
            }
            let dominated = b.run.max_peak_reserved() <= a.run.max_peak_reserved()
                && b.run.step_time_us <= a.run.step_time_us
                && (b.run.max_peak_reserved() < a.run.max_peak_reserved()
                    || b.run.step_time_us < a.run.step_time_us);
            assert!(!dominated, "frontier point dominated");
        }
    }
}

#[test]
fn placement_sweep_covers_two_gpus_with_peaks_and_step_times() {
    // The `rlhf-mem cluster` acceptance shape: a >= 2-GPU sweep where every
    // configuration reports per-GPU peaks and a positive step time.
    let budget = tiny_budget();
    let report = plan_cluster(&budget, 2).unwrap();
    for o in &report.outcomes {
        assert!(o.candidate.world >= 2);
        assert_eq!(o.run.gpus.len() as u64, o.candidate.world);
        for g in &o.run.gpus {
            assert!(g.peak_reserved > 0);
        }
        assert!(o.run.step_time_us > 0.0);
    }
}
