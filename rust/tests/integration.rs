//! Integration tests across the full memory-study stack: scenario → trace
//! → allocator → profiler, for every framework/strategy combination the
//! paper evaluates, plus property-style invariant sweeps.

use rlhf_mem::alloc::CachingAllocator;
use rlhf_mem::experiment::{run_scenario, RTX3090_HBM};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::sim::{build_trace, ScenarioMode, SimScenario};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::trace::{replay, NullPhaseSink};
use rlhf_mem::util::bytes::GIB;
use rlhf_mem::util::prng::Rng;

fn all_strategies() -> Vec<StrategyConfig> {
    vec![
        StrategyConfig::none(),
        StrategyConfig::zero1(),
        StrategyConfig::zero2(),
        StrategyConfig::zero3(),
        StrategyConfig::zero3_offload(),
        StrategyConfig::checkpointing(),
        StrategyConfig::all_enabled(),
    ]
}

#[test]
fn every_ds_strategy_fits_24gib_and_validates() {
    for strat in all_strategies() {
        let mut scn = SimScenario::deepspeed_opt(strat, EmptyCachePolicy::Never);
        scn.steps = 1;
        let trace = build_trace(&scn);
        let mut alloc = CachingAllocator::with_default_config(RTX3090_HBM);
        let res = replay(&trace, &mut alloc, &mut NullPhaseSink);
        assert!(res.ok(), "{strat:?} OOMed: {:?}", res.oom);
        alloc.validate().unwrap_or_else(|e| panic!("{strat:?}: {e}"));
    }
}

#[test]
fn every_colossal_strategy_validates() {
    for strat in StrategyConfig::table1_colossal_rows().into_iter().map(|(_, s)| s) {
        for scn in [
            SimScenario::colossal_opt(strat, EmptyCachePolicy::Never),
            SimScenario::colossal_gpt2(strat, EmptyCachePolicy::Never),
        ] {
            let mut scn = scn;
            scn.steps = 1;
            let trace = build_trace(&scn);
            let mut alloc = CachingAllocator::with_default_config(RTX3090_HBM);
            let res = replay(&trace, &mut alloc, &mut NullPhaseSink);
            assert!(res.ok(), "{strat:?} OOMed");
            alloc.validate().unwrap();
        }
    }
}

#[test]
fn traces_are_balanced_after_teardown() {
    // Leaked handles must be exactly the persistent engine state (params,
    // adapters, optimizer, comm machinery) — nothing from per-step work.
    use rlhf_mem::trace::TraceOp;
    let mut scn = SimScenario::deepspeed_opt(StrategyConfig::zero2(), EmptyCachePolicy::Never);
    scn.steps = 2;
    let trace = build_trace(&scn);
    let leaked = trace.check_balanced().unwrap();
    // Count Init-phase allocs: every leak must have been allocated before
    // the first Generation phase marker.
    let mut init_handles = std::collections::HashSet::new();
    for op in &trace.ops {
        match op {
            TraceOp::Phase(p) if *p != rlhf_mem::trace::PhaseKind::Init => break,
            TraceOp::Alloc { handle, .. } => {
                init_handles.insert(handle.0);
            }
            _ => {}
        }
    }
    for h in &leaked {
        assert!(
            init_handles.contains(&h.0),
            "leaked handle {h:?} was not allocated in Init"
        );
    }
}

#[test]
fn paper_insight_zero3_raises_fragmentation() {
    let frag = |strat| {
        let mut scn = SimScenario::deepspeed_opt(strat, EmptyCachePolicy::Never);
        scn.steps = 2;
        run_scenario(&scn, RTX3090_HBM).summary.frag
    };
    let none = frag(StrategyConfig::none());
    let z3 = frag(StrategyConfig::zero3());
    assert!(
        z3 > none,
        "ZeRO-3 must raise fragmentation: {z3} vs {none}"
    );
}

#[test]
fn paper_insight_zero1_stably_reduces_memory() {
    let reserved = |strat| {
        let mut scn = SimScenario::deepspeed_opt(strat, EmptyCachePolicy::Never);
        scn.steps = 2;
        run_scenario(&scn, RTX3090_HBM).summary.peak_reserved
    };
    assert!(reserved(StrategyConfig::zero1()) < reserved(StrategyConfig::none()));
}

#[test]
fn paper_insight_gpt2_checkpointing_no_effect() {
    // §3.2: ColossalChat/GPT-2 peaks during inference, so checkpointing
    // barely moves the peak.
    let mut base = SimScenario::colossal_gpt2(StrategyConfig::none(), EmptyCachePolicy::Never);
    base.steps = 2;
    let none = run_scenario(&base, RTX3090_HBM).summary;
    let mut ck = SimScenario::colossal_gpt2(StrategyConfig::checkpointing(), EmptyCachePolicy::Never);
    ck.steps = 2;
    let ckpt = run_scenario(&ck, RTX3090_HBM).summary;
    let delta = (none.peak_reserved as f64 - ckpt.peak_reserved as f64).abs()
        / none.peak_reserved as f64;
    assert!(delta < 0.05, "checkpointing moved GPT-2 peak by {delta:.3}");
}

#[test]
fn paper_insight_empty_cache_cuts_fragmentation() {
    let mut never = SimScenario::colossal_gpt2(StrategyConfig::zero3(), EmptyCachePolicy::Never);
    never.steps = 3;
    let mut ec = never.clone();
    ec.policy = EmptyCachePolicy::AfterBoth;
    let a = run_scenario(&never, RTX3090_HBM).summary;
    let b = run_scenario(&ec, RTX3090_HBM).summary;
    assert!(b.frag < a.frag, "empty_cache must cut fragmentation: {} vs {}", b.frag, a.frag);
    assert!(b.peak_reserved <= a.peak_reserved);
}

#[test]
fn scenario_modes_ordering() {
    // §3.1: full > train-both > actor-only in reserved memory.
    let run = |mode| {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::all_enabled(), EmptyCachePolicy::Never);
        scn.steps = 2;
        scn.mode = mode;
        run_scenario(&scn, RTX3090_HBM).summary.peak_reserved
    };
    let full = run(ScenarioMode::Full);
    let both = run(ScenarioMode::TrainBothPrecollected);
    let actor = run(ScenarioMode::TrainActorOnly);
    assert!(full >= both, "{full} vs {both}");
    assert!(both >= actor, "{both} vs {actor}");
}

#[test]
fn property_random_traces_never_break_allocator() {
    // Property sweep: random mixed workloads with interleaved empty_cache
    // must preserve every allocator invariant and end balanced.
    let mut rng = Rng::seeded(0xFEED);
    for case in 0..30 {
        let mut alloc = CachingAllocator::with_default_config(2 * GIB);
        let mut live = Vec::new();
        let ops = 2_000;
        for _ in 0..ops {
            match rng.gen_range(10) {
                0..=5 => {
                    let sz = match rng.gen_range(3) {
                        0 => rng.gen_range(512 * 1024) + 1,
                        1 => rng.gen_range(8 << 20) + (1 << 20),
                        _ => rng.gen_range(64 << 20) + (10 << 20),
                    };
                    if let Ok(h) = alloc.alloc(sz) {
                        live.push(h);
                    }
                }
                6..=8 => {
                    if !live.is_empty() {
                        let i = rng.range_usize(0, live.len());
                        alloc.free(live.swap_remove(i));
                    }
                }
                _ => {
                    alloc.empty_cache();
                }
            }
        }
        alloc.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
        for h in live.drain(..) {
            alloc.free(h);
        }
        alloc.empty_cache();
        assert_eq!(alloc.reserved(), 0, "case {case} leaked reserved memory");
        alloc.validate().unwrap();
    }
}

#[test]
fn deterministic_given_seed() {
    let mk = || {
        let mut scn = SimScenario::colossal_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        scn.steps = 2;
        run_scenario(&scn, RTX3090_HBM).summary
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.peak_reserved, b.peak_reserved);
    assert_eq!(a.frag, b.frag);
    assert_eq!(a.peak_allocated, b.peak_allocated);
}
