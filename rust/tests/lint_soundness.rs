//! Soundness of the static peak bounds (`lint::bounds`) against the
//! simulator, proven over the full battery rather than argued once:
//!
//! - `lo <= phase_peaks(trace) <= hi` for every phase, across
//!   algorithm × sharing × strategy (DeepSpeed), mode × framework
//!   (including ColossalChat's ragged lengths and scorer offload), and
//!   every placement preset's per-GPU derived scenario;
//! - `init`'s simulated peak is *exactly* the static footprint for
//!   generating pipelines (nothing silently loads into the init phase);
//! - the planner's `--prescreen-static` prunes only statically-proven
//!   infeasible candidates, so the surviving Pareto frontier is
//!   byte-identical to the unscreened run.

use rlhf_mem::coordinator::PlacementPlan;
use rlhf_mem::lint::{static_bounds, static_lower_max};
use rlhf_mem::planner::space::{candidate_scenario, enumerate};
use rlhf_mem::planner::{plan, plan_with, Budget, PlanOptions};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::program::{Algo, Sharing};
use rlhf_mem::rlhf::sim::{self, ScenarioMode, SimScenario, SCENARIO_PRESETS};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::trace::analysis::phase_peaks;
use rlhf_mem::trace::PhaseKind;

/// Assert every simulated phase peak of `scn` falls inside its static
/// interval.
fn assert_bracketed(scn: &SimScenario, label: &str) {
    let bounds = static_bounds(scn);
    let trace = sim::build_trace(scn);
    for (phase, peak) in phase_peaks(&trace) {
        let b = bounds
            .iter()
            .find(|b| b.phase == phase)
            .unwrap_or_else(|| panic!("{label}: no static bound for phase {}", phase.name()));
        assert!(
            b.lo <= peak && peak <= b.hi,
            "{label}/{}: simulated peak {} outside static [{}, {}]",
            phase.name(),
            peak,
            b.lo,
            b.hi
        );
    }
}

#[test]
fn bounds_bracket_every_algo_sharing_strategy_cell() {
    for algo in Algo::ALL {
        for sharing in Sharing::ALL {
            for (row, strategy) in StrategyConfig::table1_deepspeed_rows() {
                let mut scn =
                    SimScenario::deepspeed_opt(strategy, EmptyCachePolicy::Never);
                scn.steps = 2;
                scn.algo = algo;
                scn.sharing = sharing;
                assert_bracketed(
                    &scn,
                    &format!("{}/{}/{row}", algo.name(), sharing.name()),
                );
            }
        }
    }
}

#[test]
fn bounds_bracket_every_mode_and_framework_cell() {
    for preset in &SCENARIO_PRESETS {
        for mode in ScenarioMode::ALL {
            for (row, strategy) in StrategyConfig::table1_deepspeed_rows() {
                // Presets keep the framework's length-jitter default, so
                // ColossalChat cells run ragged lengths here.
                let mut scn = preset.build(strategy, EmptyCachePolicy::AfterBoth);
                if !scn.framework.supports(&scn.strategy) {
                    continue;
                }
                scn.steps = 2;
                scn.mode = mode;
                assert_bracketed(&scn, &format!("{}/{}/{row}", preset.name, mode.name()));
            }
        }
    }
}

#[test]
fn bounds_bracket_every_placement_gpu() {
    let strategies = [
        StrategyConfig::none(),
        StrategyConfig::zero3(),
        StrategyConfig::zero3_offload(),
    ];
    for plan in PlacementPlan::presets(4) {
        for algo in [Algo::Ppo, Algo::Grpo, Algo::Dpo] {
            for strategy in strategies {
                let mut base =
                    SimScenario::deepspeed_opt(strategy, EmptyCachePolicy::Never);
                base.steps = 2;
                base.algo = algo;
                for g in 0..plan.hosted.len() {
                    if plan.hosted[g].intersect(algo.roles()).is_empty() {
                        continue;
                    }
                    let scn = plan.scenario_for_gpu(&base, g);
                    assert_bracketed(
                        &scn,
                        &format!("{}/{}/gpu{g}", plan.name, algo.name()),
                    );
                }
            }
        }
    }
}

#[test]
fn init_peak_is_exactly_the_static_footprint() {
    for preset in &SCENARIO_PRESETS {
        for (row, strategy) in StrategyConfig::table1_deepspeed_rows() {
            let mut scn = preset.build(strategy, EmptyCachePolicy::Never);
            if !scn.framework.supports(&scn.strategy) {
                continue;
            }
            scn.steps = 1;
            // PPO generates, so the first marked phase after init is
            // Generation: init's peak is the engine footprint, exactly.
            let p = sim::init_footprint(&scn).total();
            let peaks = phase_peaks(&sim::build_trace(&scn));
            let init = peaks
                .iter()
                .find(|(k, _)| *k == PhaseKind::Init)
                .expect("trace has an init phase")
                .1;
            assert_eq!(init, p, "{}/{row}", preset.name);
        }
    }
}

#[test]
fn prescreen_is_identity_when_everything_clears_the_floor() {
    let mut budget = Budget::rtx3090_table1();
    budget.steps = 1;
    budget.strategies = Some(vec!["none".to_string(), "zero3".to_string()]);
    budget.allocators = Some(vec!["default".to_string()]);

    let plain = plan(&budget, 2).unwrap();
    let screened = plan_with(
        &budget,
        2,
        PlanOptions {
            prescreen_static: true,
        },
    )
    .unwrap();
    assert_eq!(plain.static_pruned, None);
    assert_eq!(screened.static_pruned, Some(0));
    assert_eq!(plain.outcomes.len(), screened.outcomes.len());
    assert_eq!(
        plain.frontier_jsonl(),
        screened.frontier_jsonl(),
        "prescreen must not change the frontier"
    );
    assert!(!screened.frontier_jsonl().is_empty(), "24 GiB fits something");
}

#[test]
fn prescreen_prunes_proven_infeasible_groups_and_keeps_the_frontier() {
    // Self-calibrating capacity: with separate vs hydra placements of the
    // same "none" strategy, the full-replica group's static floor sits
    // well above the shared-trunk group's. A capacity one byte below the
    // separate floor proves that whole group infeasible while hydra
    // still runs.
    let mut budget = Budget::rtx3090_table1();
    budget.steps = 1;
    budget.strategies = Some(vec!["none".to_string()]);
    budget.allocators = Some(vec!["default".to_string()]);
    budget.sharings = Some(vec!["separate".to_string(), "hydra".to_string()]);

    let cands = enumerate(&budget).unwrap();
    let floor_of =
        |sharing: Sharing| -> u64 {
            cands
                .iter()
                .filter(|c| c.sharing == sharing)
                .map(|c| static_lower_max(&candidate_scenario(&budget, c)))
                .max()
                .expect("candidates exist for the sharing")
        };
    let separate_floor = floor_of(Sharing::Separate);
    let hydra_floor = floor_of(Sharing::Hydra);
    assert!(
        hydra_floor < separate_floor,
        "shared trunk must undercut full replicas: {hydra_floor} vs {separate_floor}"
    );
    let separate_count = cands
        .iter()
        .filter(|c| c.sharing == Sharing::Separate)
        .count() as u64;

    budget.capacity = separate_floor - 1;
    let plain = plan(&budget, 2).unwrap();
    let screened = plan_with(
        &budget,
        2,
        PlanOptions {
            prescreen_static: true,
        },
    )
    .unwrap();
    assert_eq!(screened.static_pruned, Some(separate_count));
    assert!(screened
        .outcomes
        .iter()
        .all(|o| o.candidate.sharing == Sharing::Hydra));
    // Pruned candidates were infeasible, so the frontier is untouched.
    assert_eq!(plain.frontier_jsonl(), screened.frontier_jsonl());
    // Survivors keep their enumeration identity: both runs' hydra lines
    // agree index for index.
    let plain_hydra: Vec<usize> = plain
        .outcomes
        .iter()
        .filter(|o| o.candidate.sharing == Sharing::Hydra)
        .map(|o| o.candidate.index)
        .collect();
    let screened_hydra: Vec<usize> =
        screened.outcomes.iter().map(|o| o.candidate.index).collect();
    assert_eq!(plain_hydra, screened_hydra);

    // Below every floor the prescreen rejects the whole space, loudly.
    budget.capacity = hydra_floor.min(separate_floor) - 1;
    let err = plan_with(
        &budget,
        2,
        PlanOptions {
            prescreen_static: true,
        },
    )
    .unwrap_err();
    assert!(err.contains("static prescreen rejected all"), "{err}");
}
