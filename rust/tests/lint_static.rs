//! Mutation battery for the static verifier: seed known-bad phase
//! programs, ownership tables, placement plans and capacities, and
//! assert the exact diagnostic code each defect fires — then prove the
//! shipping presets and `examples/*.json` configs are deny-free under
//! the strictest severity configuration.

use rlhf_mem::config::ExperimentConfig;
use rlhf_mem::coordinator::PlacementPlan;
use rlhf_mem::lint::dataflow::{StaticAlloc, StaticAllocKind};
use rlhf_mem::lint::{
    check_ownership, check_plan, check_program, lint_plan, lint_scenario, static_bounds,
    static_lower_max, Finding, LintConfig, Severity,
};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::models::{Role, RoleSet};
use rlhf_mem::rlhf::program::{Algo, PhaseBody, PhaseNode, PhaseProgram, Sharing};
use rlhf_mem::rlhf::sim::{self, SimScenario, SCENARIO_PRESETS};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::trace::PhaseKind;
use rlhf_mem::util::bytes::GIB;

fn codes(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.code).collect()
}

fn ppo() -> SimScenario {
    SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never)
}

#[test]
fn double_free_fires_rlhf002() {
    let scn = ppo();
    let mut program = PhaseProgram::compile(&scn);
    // The compiled step already ends in FreeExperience; a second free
    // runs with nothing live.
    program.nodes.push(PhaseNode {
        kind: None,
        requires: RoleSet::EMPTY,
        body: PhaseBody::FreeExperience,
    });
    let mut f = Vec::new();
    check_program(&program, RoleSet::EMPTY, None, &mut f);
    assert_eq!(codes(&f), vec!["RLHF002"]);
}

#[test]
fn dropping_the_producer_fires_rlhf001() {
    let scn = ppo();
    let mut program = PhaseProgram::compile(&scn);
    let gen = program
        .nodes
        .iter()
        .position(|n| matches!(n.body, PhaseBody::Generation { .. }))
        .expect("PPO generates");
    program.nodes.remove(gen);
    let mut f = Vec::new();
    check_program(&program, RoleSet::EMPTY, None, &mut f);
    assert!(!f.is_empty());
    // Every downstream consumer of the rollout now reads unproduced
    // experience; nothing else is wrong with the program.
    assert!(
        f.iter().all(|x| x.code == "RLHF001"),
        "{:?}",
        codes(&f)
    );
}

#[test]
fn wrong_phase_mark_fires_rlhf006() {
    let scn = ppo();
    let mut program = PhaseProgram::compile(&scn);
    let gen = program
        .nodes
        .iter()
        .position(|n| matches!(n.body, PhaseBody::Generation { .. }))
        .expect("PPO generates");
    program.nodes[gen].kind = Some(PhaseKind::TrainActor);
    let mut f = Vec::new();
    check_program(&program, RoleSet::EMPTY, None, &mut f);
    assert_eq!(codes(&f), vec!["RLHF006"]);
}

#[test]
fn non_owner_base_alloc_fires_rlhf012() {
    let mut scn = ppo();
    scn.sharing = Sharing::Lora;
    // Under LoRA sharing the actor owns the {actor, reference} trunk;
    // a reference-side base replica duplicates it.
    let allocs = vec![StaticAlloc {
        role: Role::Reference,
        kind: StaticAllocKind::SharedBase,
        bytes: 1,
    }];
    let mut f = Vec::new();
    check_ownership(&scn, &allocs, None, &mut f);
    assert_eq!(codes(&f), vec!["RLHF012"]);
}

#[test]
fn oversized_optimizer_fires_rlhf011() {
    let mut scn = ppo();
    scn.sharing = Sharing::FrozenShared;
    let budget = 6 * sim::trainable_bytes_f16(&scn, Role::Actor);
    let allocs = vec![StaticAlloc {
        role: Role::Actor,
        kind: StaticAllocKind::Optimizer,
        bytes: budget + 1,
    }];
    let mut f = Vec::new();
    check_ownership(&scn, &allocs, None, &mut f);
    assert_eq!(codes(&f), vec!["RLHF011"]);
    // At exactly the budget the state is justified.
    let allocs = vec![StaticAlloc {
        role: Role::Actor,
        kind: StaticAllocKind::Optimizer,
        bytes: budget,
    }];
    let mut f = Vec::new();
    check_ownership(&scn, &allocs, None, &mut f);
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn partial_allreduce_overlap_fires_rlhf026() {
    // Critic hosts {1, 2} vs the actor DP group {0, 1}: rank 1 enters a
    // gradient all-reduce ranks 0/2 never join.
    let mut plan = PlacementPlan::colocated(3);
    plan.hosted = vec![
        RoleSet::of(&[Role::Actor, Role::Reference, Role::Reward]),
        RoleSet::of(&[Role::Actor, Role::Critic]),
        RoleSet::of(&[Role::Critic, Role::Reference, Role::Reward]),
    ];
    plan.time_shared = vec![RoleSet::EMPTY; 3];
    let mut f = Vec::new();
    assert!(check_plan(&plan, Algo::Ppo, Sharing::Separate, &mut f));
    assert_eq!(codes(&f), vec!["RLHF026"]);
}

#[test]
fn unhosted_generator_fires_rlhf027() {
    let mut plan = PlacementPlan::colocated(2);
    plan.hosted = vec![
        RoleSet::of(&[Role::Reference, Role::Reward]),
        RoleSet::of(&[Role::Critic, Role::Reward]),
    ];
    plan.time_shared = vec![RoleSet::EMPTY; 2];
    let mut f = Vec::new();
    assert!(check_plan(&plan, Algo::Ppo, Sharing::Separate, &mut f));
    assert!(codes(&f).contains(&"RLHF023"), "{f:?}");
    assert!(codes(&f).contains(&"RLHF027"), "{f:?}");
}

#[test]
fn time_sharing_an_unhosted_model_fires_rlhf024() {
    let mut plan = PlacementPlan::colocated(2);
    plan.hosted[0] = RoleSet::of(&[Role::Actor, Role::Critic, Role::Reward]);
    plan.time_shared[0] = RoleSet::of(&[Role::Reference]);
    let mut f = Vec::new();
    assert!(check_plan(&plan, Algo::Ppo, Sharing::Separate, &mut f));
    assert_eq!(codes(&f), vec!["RLHF024"]);
}

#[test]
fn over_budget_capacity_fires_the_bounds_rules() {
    let scn = ppo();
    let floor = static_lower_max(&scn);
    // Below the engine floor: proven infeasible, a deny.
    let report = lint_scenario(&scn, floor - 1, &LintConfig::default());
    assert!(report.deny_count() > 0);
    assert!(
        report.findings.iter().any(|x| x.code == "RLHF030"),
        "{:?}",
        codes(&report.findings)
    );
    // Between the floor and the ceiling: inconclusive, a warning only.
    let ceiling = static_bounds(&scn).iter().map(|b| b.hi).max().unwrap();
    let report = lint_scenario(&scn, ceiling - 1, &LintConfig::default());
    assert_eq!(report.deny_count(), 0);
    assert_eq!(codes(&report.findings), vec!["RLHF031"]);
    assert_eq!(report.findings[0].severity, Severity::Warn);
}

#[test]
fn severity_configuration_reshapes_the_verdict() {
    let scn = ppo();
    let ceiling = static_bounds(&scn).iter().map(|b| b.hi).max().unwrap();
    // Promote the inconclusive warning to a deny...
    let strict = LintConfig::from_lists("RLHF031", "", "").unwrap();
    let report = lint_scenario(&scn, ceiling - 1, &strict);
    assert_eq!(report.deny_count(), 1);
    // ...or suppress it entirely.
    let lax = LintConfig::from_lists("", "", "RLHF031").unwrap();
    let report = lint_scenario(&scn, ceiling - 1, &lax);
    assert!(report.findings.is_empty(), "{:?}", report.findings);
}

/// The strict shipping gate: deny everything except `RLHF031`, which is
/// inconclusive by design at realistic capacities (the static upper
/// bound cannot rule an OOM out — the simulator decides).
fn strictest() -> LintConfig {
    LintConfig::from_lists("all", "", "RLHF031").unwrap()
}

#[test]
fn presets_are_deny_free_under_the_strictest_config() {
    let cfg = strictest();
    for preset in &SCENARIO_PRESETS {
        for (row, strategy) in StrategyConfig::table1_deepspeed_rows() {
            let scn = preset.build(strategy, EmptyCachePolicy::Never);
            if !scn.framework.supports(&scn.strategy) {
                continue;
            }
            let report = lint_scenario(&scn, 24 * GIB, &cfg);
            assert_eq!(
                report.deny_count(),
                0,
                "{}/{row}: {:?}",
                preset.name,
                report.findings
            );
        }
    }
}

#[test]
fn placement_presets_are_deny_free_under_the_strictest_config() {
    let cfg = strictest();
    let base = ppo();
    for plan in PlacementPlan::presets(4) {
        for algo in Algo::ALL {
            let mut base = base.clone();
            base.algo = algo;
            let report = lint_plan(&base, &plan, 24 * GIB, &cfg);
            assert_eq!(
                report.deny_count(),
                0,
                "{}/{}: {:?}",
                plan.name,
                algo.name(),
                report.findings
            );
        }
    }
}

#[test]
fn shipped_example_configs_are_deny_free_under_the_strictest_config() {
    let cfg = strictest();
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut checked = 0;
    for entry in std::fs::read_dir(root.join("examples")).expect("read examples/") {
        let path = entry.expect("dir entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let text = std::fs::read_to_string(&path).expect("read example");
        let exp = ExperimentConfig::from_json_text(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let report = lint_scenario(&exp.scenario, exp.capacity, &cfg);
        assert_eq!(
            report.deny_count(),
            0,
            "{}: {:?}",
            path.display(),
            report.findings
        );
        checked += 1;
    }
    assert!(checked >= 2, "expected the shipped example configs");
}
