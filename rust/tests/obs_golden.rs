//! Observability golden tests: the `explain` decomposition pinned against
//! the embedded seed-allocator oracle, per-rank peak attribution for
//! cluster placements, the timeline-resolution contract, the
//! `profile --json` legacy schema, Perfetto document validity, and the
//! jobs-1 vs jobs-N byte-identity of every telemetry footer.

#[path = "support/oracle.rs"]
#[allow(dead_code)]
mod oracle;

use oracle::assert_equivalent_on_trace;
use rlhf_mem::alloc::AllocatorConfig;
use rlhf_mem::coordinator::PlacementPlan;
use rlhf_mem::experiment::{run_scenario_observed, RTX3090_HBM};
use rlhf_mem::frameworks::FrameworkKind;
use rlhf_mem::obs::{explain_scenario, profile_doc, ExplainOptions, ObsStack};
use rlhf_mem::planner::{plan, plan_cluster, Budget};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::profiler::{MemoryProfiler, Timeline};
use rlhf_mem::rlhf::program::PhaseProgram;
use rlhf_mem::rlhf::sim::{build_trace, SimScenario};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::sweep::{SweepGrid, SweepRunner};
use rlhf_mem::trace::PhaseKind;
use rlhf_mem::util::bytes::MIB;
use rlhf_mem::util::json::{parse, Json};

fn ds_opt(steps: u64) -> SimScenario {
    let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
    scn.steps = steps;
    scn
}

/// The paper's Table-1 workload, explained: the allocator behavior on the
/// exact trace is pinned against the seed oracle, and the five-way
/// decomposition must account for (at least 99% of, and by construction
/// exactly) the peak reserved bytes.
#[test]
fn explain_accounts_for_the_deepspeed_peak_against_the_oracle() {
    let scn = ds_opt(1);
    let trace = build_trace(&scn);
    assert_equivalent_on_trace(
        &AllocatorConfig::default(),
        RTX3090_HBM,
        &trace,
        "explain-golden",
    );

    let out = explain_scenario(
        &scn,
        RTX3090_HBM,
        &AllocatorConfig::default(),
        &ExplainOptions::default(),
    );
    let r = &out.report;
    assert!(!r.summary.oom, "Table-1 baseline fits 24 GiB");
    let peak = r.peak.as_ref().expect("device memory was reserved");
    assert_eq!(
        peak.reserved, r.summary.peak_reserved,
        "recorder and profiler must agree on the global peak"
    );
    assert_eq!(
        peak.breakdown.total(),
        peak.reserved,
        "census + rounding + slack + free gaps + cached-free must sum to reserved"
    );
    assert!(r.accounted_pct() >= 99.0, "{}", r.accounted_pct());

    // The tag census, the phase census, and the pool census are three
    // views of the same live-block set.
    let by_tag: u64 = peak.by_tag.iter().map(|(_, c)| c.requested).sum();
    let by_phase: u64 = peak.by_phase.iter().map(|(_, c)| c.requested).sum();
    let by_pool: u64 = peak.by_pool.iter().map(|c| c.requested).sum();
    assert_eq!(by_tag, peak.breakdown.census_requested);
    assert_eq!(by_phase, by_tag);
    assert_eq!(by_pool, by_tag);

    // The shrink table ranks descending and starts with a live class
    // (model state dominates the un-mitigated baseline).
    assert!(!r.rows.is_empty());
    for w in r.rows.windows(2) {
        assert!(w[0].bytes >= w[1].bytes);
    }
    assert!(r.rows[0].is_census, "the top consumer is a live tensor class");
}

/// Per-rank peak attribution under cluster placements: every phase a
/// GPU's profiler or recorder attributes memory to must appear in the
/// PhaseProgram compiled for that rank's scenario.
#[test]
fn cluster_rank_attribution_matches_the_compiled_program() {
    let mut base = ds_opt(1);
    base.world = 2;
    let plans = vec![
        PlacementPlan::time_shared(2),
        PlacementPlan::dedicated(2).expect("2 GPUs is enough for dedicated"),
    ];
    for plan in &plans {
        for g in 0..plan.gpus() as usize {
            let scn = plan.scenario_for_gpu(&base, g);
            let program = PhaseProgram::compile(&scn);
            let mut allowed: Vec<PhaseKind> = vec![PhaseKind::Init];
            allowed.extend(program.step_phases());

            let mut obs = ObsStack::new();
            let outcome =
                run_scenario_observed(&scn, RTX3090_HBM, &AllocatorConfig::default(), &mut obs);
            assert!(!outcome.summary.oom, "{}/gpu{g}", plan.name);

            for phase in obs.profiler.phase_peaks.keys() {
                assert!(
                    allowed.contains(phase),
                    "{}/gpu{g}: profiler peak in unscheduled phase {}",
                    plan.name,
                    phase.name()
                );
            }
            let peak = obs.recorder.peak().expect("rank reserved memory");
            assert!(
                allowed.contains(&peak.phase),
                "{}/gpu{g}: global peak in unscheduled phase {}",
                plan.name,
                peak.phase.name()
            );
            for (phase, _) in &peak.by_phase {
                assert!(allowed.contains(phase), "{}/gpu{g}", plan.name);
            }
            // Attribution is program-ordered and non-empty for a rank
            // that reserved memory.
            let attr = obs.profiler.phase_attribution(&program);
            assert!(!attr.is_empty(), "{}/gpu{g}", plan.name);
        }
    }
}

/// The decimation floor is a constructor parameter with a pinned default
/// of 16 MiB; a finer resolution never yields fewer timeline points.
#[test]
fn timeline_resolution_default_is_16_mib_and_tunable() {
    assert_eq!(Timeline::new().resolution(), 16 * MIB);
    assert_eq!(MemoryProfiler::new().timeline.resolution(), 16 * MIB);

    let scn = ds_opt(1);
    let mut coarse = ObsStack::with_profiler(MemoryProfiler::with_timeline_resolution(256 * MIB));
    run_scenario_observed(&scn, RTX3090_HBM, &AllocatorConfig::default(), &mut coarse);
    let mut fine = ObsStack::with_profiler(MemoryProfiler::with_timeline_resolution(MIB));
    run_scenario_observed(&scn, RTX3090_HBM, &AllocatorConfig::default(), &mut fine);

    let coarse_n = coarse.profiler.timeline.points().len();
    let fine_n = fine.profiler.timeline.points().len();
    assert!(coarse_n > 0);
    assert!(
        fine_n >= coarse_n,
        "1 MiB resolution kept {fine_n} points vs {coarse_n} at 256 MiB"
    );
}

/// `profile --json` schema: the five legacy scalar keys keep their names
/// and order (external consumers index into them); the attribution and
/// empty-cache extensions ride behind.
#[test]
fn profile_doc_legacy_keys_stay_first() {
    let scn = ds_opt(1);
    let mut obs = ObsStack::new();
    let outcome = run_scenario_observed(&scn, RTX3090_HBM, &AllocatorConfig::default(), &mut obs);
    let program = PhaseProgram::compile(&scn);
    let doc = profile_doc(&outcome.summary, &obs.profiler, &program);

    let Json::Obj(kvs) = &doc else {
        panic!("profile_doc must be a JSON object")
    };
    let keys: Vec<&str> = kvs.iter().map(|(k, _)| k.as_str()).collect();
    assert_eq!(
        &keys[..5],
        &["reserved", "frag", "allocated", "peak_phase", "oom"],
        "legacy profile --json schema must stay stable"
    );
    assert!(keys.contains(&"phase_attribution"));
    assert!(keys.contains(&"frag_samples"));
    assert!(keys.contains(&"empty_cache_calls"));

    let parsed = parse(&doc.to_string_pretty()).unwrap();
    assert_eq!(
        parsed.req_u64("reserved").unwrap(),
        outcome.summary.peak_reserved
    );
    let attr = parsed.req_arr("phase_attribution").unwrap();
    assert!(!attr.is_empty());
    for entry in attr {
        assert!(entry.get("phase").and_then(Json::as_str).is_some());
        assert!(entry.req_u64("reserved").unwrap() > 0);
    }
}

/// The Perfetto document parses, carries counter samples, allocator
/// instants, and one span per scheduled phase — and is byte-identical
/// across two recordings of the same scenario.
#[test]
fn perfetto_trace_covers_every_scheduled_phase() {
    let scn = ds_opt(1);
    let opts = ExplainOptions {
        top_k: 3,
        perfetto_pid: Some(0),
    };
    let out = explain_scenario(&scn, RTX3090_HBM, &AllocatorConfig::default(), &opts);
    let doc = out.perfetto.expect("perfetto recorder was armed");
    let text = doc.to_json().to_string_pretty();

    let j = parse(&text).unwrap();
    let events = j.req_arr("traceEvents").unwrap();
    assert!(!events.is_empty());
    let count = |ph: &str| {
        events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some(ph))
            .count()
    };
    assert!(count("C") >= 1, "at least one counter sample");
    assert!(count("i") >= 1, "allocator instants present");
    assert!(count("M") >= 1, "process-name metadata present");

    let span_names: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .filter_map(|e| e.get("name").and_then(Json::as_str))
        .collect();
    let program = PhaseProgram::compile(&scn);
    for phase in program.step_phases() {
        assert!(
            span_names.contains(&phase.name()),
            "missing span for phase {}",
            phase.name()
        );
    }

    let again = explain_scenario(&scn, RTX3090_HBM, &AllocatorConfig::default(), &opts);
    assert_eq!(
        text,
        again.perfetto.unwrap().to_json().to_string_pretty(),
        "trace documents must be deterministic"
    );
}

/// Every telemetry-bearing artifact — sweep, planner, cluster planner —
/// is byte-identical for `--jobs 1` vs `--jobs 4`, and the footer is a
/// parseable `{"telemetry":{...}}` object with the promised counters.
#[test]
fn telemetry_footers_are_worker_count_invariant() {
    let cells = SweepGrid::new()
        .frameworks([FrameworkKind::DeepSpeedChat])
        .strategies([
            ("None", StrategyConfig::none()),
            ("ZeRO-3", StrategyConfig::zero3()),
        ])
        .policies([EmptyCachePolicy::Never, EmptyCachePolicy::AfterBoth])
        .steps(1)
        .build()
        .unwrap();
    let serial = SweepRunner::new(1).run(cells.clone()).jsonl_with_telemetry();
    let pooled = SweepRunner::new(4).run(cells).jsonl_with_telemetry();
    assert_eq!(serial, pooled, "sweep JSONL + footer must not depend on --jobs");

    let footer = serial.lines().last().unwrap();
    let j = parse(footer).unwrap();
    let t = j.get("telemetry").expect("footer carries a telemetry object");
    assert_eq!(t.req_u64("cells").unwrap(), 4);
    assert!(t.req_u64("num_allocs").unwrap() > 0);
    assert!(t.req_u64("cuda_mallocs").unwrap() > 0);
    assert!(t.get("wall_seconds").is_none(), "wall-clock never enters artifacts");

    let mut b = Budget::rtx3090_table1();
    b.steps = 1;
    b.strategies = Some(vec!["none".to_string(), "zero3".to_string()]);
    b.allocators = Some(vec!["default".to_string()]);
    let plan_serial = plan(&b, 1).unwrap().jsonl_with_telemetry();
    let plan_pooled = plan(&b, 4).unwrap().jsonl_with_telemetry();
    assert_eq!(plan_serial, plan_pooled);
    let pf = parse(plan_serial.lines().last().unwrap()).unwrap();
    let pt = pf.get("telemetry").expect("planner footer");
    assert!(pt.req_u64("candidates").unwrap() > 0);

    let mut cb = Budget::rtx3090_table1();
    cb.steps = 1;
    cb.strategies = Some(vec!["none".to_string()]);
    cb.worlds = Some(vec![2]);
    let cl_serial = plan_cluster(&cb, 1).unwrap().jsonl_with_telemetry();
    let cl_pooled = plan_cluster(&cb, 4).unwrap().jsonl_with_telemetry();
    assert_eq!(cl_serial, cl_pooled);
    let cf = parse(cl_serial.lines().last().unwrap()).unwrap();
    let ct = cf.get("telemetry").expect("cluster-planner footer");
    assert!(ct.req_u64("gpu_runs").unwrap() >= 2);
}
