//! Golden pins for the model-sharing axis (`Sharing`).
//!
//! Three layers of protection:
//!
//! 1. **`separate` is the pre-axis emitter.** The axis threaded a new
//!    field through the whole stack; under `Sharing::Separate` every
//!    role must still allocate exactly what the pre-axis emitter
//!    allocated. With no frozen toolchain to diff binaries against, the
//!    pin is a hand-written oracle: the persistent-engine byte totals
//!    (fp16 replicas, LoRA adapters, Adam state, the hybrid-engine
//!    duplicate) recomputed in this file from the public memory models,
//!    compared **exactly** against the trace's allocations.
//! 2. **The Efficient-RLHF ordering** (arXiv 2309.00754): Hydra-PPO
//!    under LoRA-PPO under full-PPO peak memory, per algorithm, with the
//!    headline reduction gated to a band the way `table1
//!    --compare-paper` gates the paper's numbers.
//! 3. **Axis activity:** non-separate placements must actually change
//!    the op stream (`Trace::fingerprint`), and the default-constructed
//!    scenario must be bit-identical to an explicit `separate`.

use rlhf_mem::experiment::{run_scenario, RTX3090_HBM};
use rlhf_mem::mem::lora::lora_tensors;
use rlhf_mem::mem::{adam_state_tensors, AdamConfig, DType, LoraSpec, TensorSpec};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::models::Role;
use rlhf_mem::rlhf::program::{Algo, Sharing};
use rlhf_mem::rlhf::sim::{build_trace, SimScenario};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::trace::{Tag, Trace, TraceOp};

fn scenario(algo: Algo, sharing: Sharing) -> SimScenario {
    let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
    scn.steps = 1;
    scn.algo = algo;
    scn.sharing = sharing;
    scn
}

fn alloc_bytes(t: &Trace, want: Tag) -> u64 {
    t.ops
        .iter()
        .filter_map(|op| match op {
            TraceOp::Alloc { tag, bytes, .. } if *tag == want => Some(*bytes),
            _ => None,
        })
        .sum()
}

/// The pre-axis persistent-engine sizing, recomputed by hand for the
/// DeepSpeed-Chat/OPT preset with the `None` strategy row (ZeRO-0, no
/// offload, the paper's global LoRA on the actor): per active role one
/// full fp16 replica, actor LoRA adapters, Adam state over the trainable
/// set (actor: adapters; critic: everything — DeepSpeed-Chat's scripts
/// leave `critic_lora_dim 0`), plus the hybrid engine's per-layer second
/// actor copy. Under ZeRO-0 nothing else in the trace carries these
/// tags, so the totals pin the Init allocations exactly.
fn legacy_oracle(scn: &SimScenario) -> (u64, u64) {
    assert!(scn.sharing == Sharing::Separate);
    let spec = scn.strategy.lora.expect("paper strategies carry LoRA");
    assert_eq!(spec, LoraSpec::paper_default());
    let active = scn.roles.intersect(scn.algo.roles());
    let mut param = 0u64;
    let mut opt = 0u64;
    for role in Role::ALL {
        if !active.contains(role) {
            continue;
        }
        let inv = scn.models.inventory_for(role);
        param += inv.tensors.iter().map(|t| t.bytes(DType::F16)).sum::<u64>();
        if !role.is_trainable() {
            continue;
        }
        let trainable: Vec<TensorSpec> = if role == Role::Actor {
            lora_tensors(&inv, spec)
        } else {
            inv.tensors.clone()
        };
        if role == Role::Actor {
            param += trainable.iter().map(|t| t.bytes(DType::F16)).sum::<u64>();
        }
        let refs: Vec<&TensorSpec> = trainable.iter().collect();
        opt += adam_state_tensors(&refs, AdamConfig::default())
            .iter()
            .map(|s| s.bytes)
            .sum::<u64>();
    }
    // DeepSpeed-Chat hybrid engine: a second per-layer actor copy.
    if scn.framework.hybrid_engine && active.contains(Role::Actor) {
        let inv = scn.models.inventory_for(Role::Actor);
        for l in 0..inv.arch.n_layers {
            param += inv.layer_bytes(l, DType::F16);
        }
    }
    (param, opt)
}

#[test]
fn separate_allocations_equal_the_pre_axis_oracle_exactly() {
    for algo in Algo::ALL {
        let scn = scenario(algo, Sharing::Separate);
        let trace = build_trace(&scn);
        let (param, opt) = legacy_oracle(&scn);
        assert_eq!(
            alloc_bytes(&trace, Tag::Param),
            param,
            "{}: fp16/adapter bytes drifted from the pre-axis emitter",
            algo.name()
        );
        assert_eq!(
            alloc_bytes(&trace, Tag::OptState),
            opt,
            "{}: Adam-state bytes drifted from the pre-axis emitter",
            algo.name()
        );
    }
}

#[test]
fn separate_is_bit_identical_to_the_default_axis_value() {
    for algo in Algo::ALL {
        let mut default_scn =
            SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::AfterBoth);
        default_scn.steps = 2;
        default_scn.algo = algo;
        assert_eq!(default_scn.sharing, Sharing::Separate, "presets default to separate");
        let mut explicit = default_scn.clone();
        explicit.sharing = Sharing::Separate;
        assert_eq!(
            build_trace(&default_scn).fingerprint(),
            build_trace(&explicit).fingerprint(),
            "{}",
            algo.name()
        );
    }
}

#[test]
fn non_separate_placements_change_the_op_stream() {
    for algo in Algo::ALL {
        let separate = build_trace(&scenario(algo, Sharing::Separate)).fingerprint();
        for sharing in [Sharing::Lora, Sharing::Hydra, Sharing::FrozenShared] {
            let shared = build_trace(&scenario(algo, sharing)).fingerprint();
            assert_ne!(
                shared,
                separate,
                "{}/{}: sharing placement left the trace untouched",
                algo.name(),
                sharing.name()
            );
        }
    }
}

#[test]
fn perl_shares_the_value_side_only() {
    // PERL (arXiv 2403.10704) trains reward-side adapters over a frozen
    // shared value backbone: it changes the op stream exactly when the
    // cast trains a value-head role. PPO's critic does; the critic-free
    // casts degrade to separate bit-for-bit (the reward scorer is a
    // frozen replica either way).
    for algo in Algo::ALL {
        let separate = build_trace(&scenario(algo, Sharing::Separate)).fingerprint();
        let perl = build_trace(&scenario(algo, Sharing::Perl)).fingerprint();
        if algo.roles().contains(Role::Critic) {
            assert_ne!(perl, separate, "{}", algo.name());
        } else {
            assert_eq!(perl, separate, "{}", algo.name());
        }
    }
    // Value-side-only sharing sits strictly between full LoRA sharing
    // and separate replicas on the PPO cast: it keeps both policy
    // replicas (unlike lora) but drops one value backbone and the
    // critic's full-model Adam state (unlike separate).
    let peak = |sharing: Sharing| {
        let s = run_scenario(&scenario(Algo::Ppo, sharing), RTX3090_HBM).summary;
        assert!(!s.oom, "{}", sharing.name());
        s.peak_reserved
    };
    let separate = peak(Sharing::Separate);
    let lora = peak(Sharing::Lora);
    let perl = peak(Sharing::Perl);
    assert!(lora < perl, "lora {lora} must undercut perl {perl}");
    assert!(perl < separate, "perl {perl} must undercut separate {separate}");
}

#[test]
fn efficient_rlhf_peak_ordering_holds_per_algo() {
    for algo in Algo::ALL {
        let peak = |sharing: Sharing| {
            let s = run_scenario(&scenario(algo, sharing), RTX3090_HBM).summary;
            assert!(!s.oom, "{}/{}", algo.name(), sharing.name());
            s.peak_reserved
        };
        let separate = peak(Sharing::Separate);
        let lora = peak(Sharing::Lora);
        let hydra = peak(Sharing::Hydra);
        let frozen = peak(Sharing::FrozenShared);
        assert!(
            lora < separate,
            "{}: lora {lora} must undercut separate {separate}",
            algo.name()
        );
        // DPO's two-role cast (actor + reference) makes the hydra and
        // lora placements coincide; every multi-role cast separates them.
        if algo == Algo::Dpo {
            assert!(hydra <= lora, "{}: hydra {hydra} vs lora {lora}", algo.name());
        } else {
            assert!(hydra < lora, "{}: hydra {hydra} vs lora {lora}", algo.name());
        }
        assert!(
            frozen < separate,
            "{}: frozen-shared {frozen} must undercut separate {separate}",
            algo.name()
        );
    }
}

#[test]
fn efficient_rlhf_reduction_ratio_stays_in_the_gated_band() {
    // Efficient-RLHF reports Hydra-PPO saving ~65% of persistent memory;
    // peak reserved also carries activations and KV caches the backbone
    // trick cannot touch, so the gate is a band, not a point — the same
    // posture `table1 --compare-paper` takes for the paper's numbers.
    let peak = |sharing: Sharing| {
        run_scenario(&scenario(Algo::Ppo, sharing), RTX3090_HBM)
            .summary
            .peak_reserved as f64
    };
    let separate = peak(Sharing::Separate);
    let hydra_reduction = 1.0 - peak(Sharing::Hydra) / separate;
    assert!(
        (0.30..=0.85).contains(&hydra_reduction),
        "hydra reduction {hydra_reduction:.2} outside [0.30, 0.85]"
    );
    let lora_reduction = 1.0 - peak(Sharing::Lora) / separate;
    assert!(
        lora_reduction >= 0.15,
        "lora reduction {lora_reduction:.2} under 15%"
    );
    assert!(
        hydra_reduction >= lora_reduction,
        "hydra must save at least as much as lora"
    );
}
