//! Planner integration tests: the golden determinism contract (`advise`
//! with `--jobs 1` vs `--jobs 4` is byte-identical) and the paper's §3.3
//! sanity anchor (for the Table-1 RTX-3090 budget, phase-boundary
//! `empty_cache` sits on the memory-vs-time frontier at ≈ 2% modeled
//! overhead).

use rlhf_mem::planner::{plan, Budget};
use rlhf_mem::policy::EmptyCachePolicy;

fn narrowed_budget() -> Budget {
    let mut b = Budget::rtx3090_table1();
    b.steps = 1;
    b.strategies = Some(vec!["none".to_string(), "zero3".to_string()]);
    b.allocators = Some(vec![
        "default".to_string(),
        "expandable".to_string(),
        "gc:0.80".to_string(),
    ]);
    b
}

#[test]
fn advise_jobs1_and_jobs4_are_byte_identical() {
    let budget = narrowed_budget();
    let serial = plan(&budget, 1).unwrap();
    let pooled = plan(&budget, 4).unwrap();
    assert_eq!(
        serial.jsonl(),
        pooled.jsonl(),
        "recommendation JSONL must not depend on the worker count"
    );
    assert_eq!(
        serial.to_json().to_string_pretty(),
        pooled.to_json().to_string_pretty(),
        "the full report document must not depend on the worker count"
    );
    assert_eq!(
        serial.best().map(|o| o.candidate.key()),
        pooled.best().map(|o| o.candidate.key()),
    );
    assert_eq!(pooled.jobs, 4);
}

#[test]
fn advise_with_algo_axis_is_jobs_deterministic() {
    let mut budget = narrowed_budget();
    budget.allocators = Some(vec!["default".to_string()]);
    budget.algos = Some(vec![
        "ppo".to_string(),
        "grpo".to_string(),
        "dpo".to_string(),
    ]);
    let serial = plan(&budget, 1).unwrap();
    let pooled = plan(&budget, 4).unwrap();
    assert_eq!(serial.jsonl(), pooled.jsonl());
    // 3 algos × 2 strategies × 4 policies × 1 allocator.
    assert_eq!(serial.outcomes.len(), 3 * 2 * 4);
    // Overheads are measured within one algorithm's workload: every
    // un-mitigated baseline is its own zero, whatever the algo.
    for o in &serial.outcomes {
        if o.candidate.policy == EmptyCachePolicy::Never
            && o.candidate.alloc_label == "default"
            && !o.summary.oom
        {
            assert_eq!(o.overhead_pct, Some(0.0), "{}", o.candidate.key());
        }
    }
}

#[test]
fn advise_with_sharing_axis_is_jobs_deterministic() {
    let mut budget = narrowed_budget();
    budget.allocators = Some(vec!["default".to_string()]);
    budget.sharings = Some(vec![
        "separate".to_string(),
        "lora".to_string(),
        "hydra".to_string(),
    ]);
    let serial = plan(&budget, 1).unwrap();
    let pooled = plan(&budget, 4).unwrap();
    assert_eq!(serial.jsonl(), pooled.jsonl());
    // 3 sharings × 2 strategies × 4 policies × 1 allocator.
    assert_eq!(serial.outcomes.len(), 3 * 2 * 4);
    // Overheads are measured within one placement's workload: every
    // un-mitigated baseline is its own zero, whatever the sharing.
    for o in &serial.outcomes {
        if o.candidate.policy == EmptyCachePolicy::Never
            && o.candidate.alloc_label == "default"
            && !o.summary.oom
        {
            assert_eq!(o.overhead_pct, Some(0.0), "{}", o.candidate.key());
        }
    }
    // The shared-backbone placements must dominate the recommendation:
    // same workload semantics, strictly less memory.
    let best = serial.best().expect("something fits");
    assert_ne!(best.candidate.sharing.name(), "separate");
}

#[test]
fn advise_reproduces_itself_across_runs() {
    let budget = narrowed_budget();
    let a = plan(&budget, 3).unwrap();
    let b = plan(&budget, 3).unwrap();
    assert_eq!(a.jsonl(), b.jsonl());
}

#[test]
fn example_budget_file_round_trips_through_the_planner() {
    let mut budget =
        Budget::from_file("examples/budget_rtx3090.json").expect("example budget parses");
    assert_eq!(budget.name, "rtx3090-table1");
    assert_eq!(budget.seed, 0x5EED);
    // Narrow the space to keep the test fast; the full-space run is the
    // `advise` command / benches/planner.rs.
    budget.steps = 1;
    budget.strategies = Some(vec!["none".to_string()]);
    budget.allocators = Some(vec!["default".to_string()]);
    let report = plan(&budget, 2).unwrap();
    // 1 strategy × 4 policies × 1 allocator × the example file's two
    // sharing placements (separate, lora).
    assert_eq!(report.outcomes.len(), 8);
    assert!(report.best().is_some(), "the paper's testbed fits 24 GiB");
}

#[test]
fn paper_anchor_empty_cache_on_frontier_within_two_percent() {
    // The paper's own conclusion, reproduced by the search: with the
    // Table-1 RTX-3090 budget and the paper's mitigation space (the stock
    // allocator — the paper predates the planner's extra knobs), placing
    // empty_cache() at phase boundaries is Pareto-optimal and costs ≈ 2%
    // modeled time.
    let mut budget = Budget::rtx3090_table1();
    budget.strategies = Some(vec!["none".to_string(), "zero3".to_string()]);
    budget.allocators = Some(vec!["default".to_string()]);
    let report = plan(&budget, 4).unwrap();

    let pct = report
        .empty_cache_frontier_overhead()
        .expect("an empty_cache placement survives frontier pruning");
    assert!(
        (-0.5..=3.0).contains(&pct),
        "phase-boundary empty_cache overhead {pct:.2}% out of the paper's ~2% band"
    );

    // And it must genuinely reduce peak reserved vs the un-mitigated
    // baseline of its strategy somewhere in the space.
    let improved = report.outcomes.iter().any(|o| {
        o.candidate.policy != EmptyCachePolicy::Never && {
            let base = report.outcomes.iter().find(|b| {
                b.candidate.strategy_label == o.candidate.strategy_label
                    && b.candidate.policy == EmptyCachePolicy::Never
                    && b.candidate.alloc_label == "default"
            });
            base.is_some_and(|b| o.summary.peak_reserved < b.summary.peak_reserved)
        }
    });
    assert!(improved, "empty_cache must lower peak reserved somewhere");
}
