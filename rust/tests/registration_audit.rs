//! Meta-test: every integration test file is actually registered.
//!
//! Because the crate lays its sources out under `rust/` instead of the
//! default `tests/`, Cargo's auto-discovery is off and every integration
//! test needs an explicit `[[test]]` block in `Cargo.toml`. A file that
//! is added without one compiles never and fails never — PR 4 found
//! `cluster_determinism.rs` silently dead this way. This test walks both
//! directions: every `rust/tests/*.rs` file has a `[[test]]` entry, and
//! every `[[test]]` entry points at an existing file. The same audit
//! covers `benches/*.rs` vs the name-only `[[bench]]` blocks, and the
//! lint diagnostic registry vs the DESIGN.md rule-catalog table.

use std::collections::BTreeSet;
use std::path::Path;

/// Minimal extraction of `[[test]]` blocks from Cargo.toml: collects the
/// `name`/`path` pairs that follow each `[[test]]` header (the manifest
/// is committed alongside this file, so the dependency-free parse only
/// has to handle the style used there: one `key = "value"` per line).
fn registered_tests(manifest: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    let mut in_test = false;
    let mut name: Option<String> = None;
    let mut path: Option<String> = None;
    let mut flush = |name: &mut Option<String>, path: &mut Option<String>| {
        if name.is_some() || path.is_some() {
            out.push((
                name.take().unwrap_or_default(),
                path.take().unwrap_or_default(),
            ));
        }
    };
    for line in manifest.lines() {
        let line = line.trim();
        if line.starts_with('[') {
            if in_test {
                flush(&mut name, &mut path);
            }
            in_test = line == "[[test]]";
            continue;
        }
        if !in_test {
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            continue;
        };
        let value = value.trim().trim_matches('"').to_string();
        match key.trim() {
            "name" => name = Some(value),
            "path" => path = Some(value),
            _ => {}
        }
    }
    if in_test {
        flush(&mut name, &mut path);
    }
    out
}

fn repo_root() -> std::path::PathBuf {
    // CARGO_MANIFEST_DIR is the repo root (Cargo.toml lives there).
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn every_test_file_is_registered_in_the_manifest() {
    let root = repo_root();
    let manifest = std::fs::read_to_string(root.join("Cargo.toml")).expect("read Cargo.toml");
    let registered: BTreeSet<String> = registered_tests(&manifest)
        .into_iter()
        .map(|(_, path)| path)
        .collect();

    let mut on_disk: BTreeSet<String> = BTreeSet::new();
    for entry in std::fs::read_dir(root.join("rust/tests")).expect("read rust/tests") {
        let entry = entry.expect("dir entry");
        if !entry.file_type().expect("file type").is_file() {
            continue; // support/ dirs hold shared helpers, not test roots
        }
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.ends_with(".rs") {
            on_disk.insert(format!("rust/tests/{name}"));
        }
    }

    let dead: Vec<&String> = on_disk.difference(&registered).collect();
    assert!(
        dead.is_empty(),
        "test files with no [[test]] block in Cargo.toml (they never run): {dead:?}"
    );
    assert!(
        on_disk.contains("rust/tests/registration_audit.rs"),
        "the audit must see itself — the directory scan is broken"
    );
}

#[test]
fn every_manifest_entry_points_at_a_real_file() {
    let root = repo_root();
    let manifest = std::fs::read_to_string(root.join("Cargo.toml")).expect("read Cargo.toml");
    let entries = registered_tests(&manifest);
    assert!(
        entries.len() >= 11,
        "expected the known [[test]] blocks, parsed only {}",
        entries.len()
    );
    for (name, path) in entries {
        assert!(!name.is_empty(), "[[test]] block without a name (path {path})");
        assert!(
            !path.is_empty(),
            "[[test]] '{name}' has no explicit path — auto-discovery is off \
             for this layout, so it would never run"
        );
        assert!(
            Path::new(&root.join(&path)).is_file(),
            "[[test]] '{name}' points at missing file {path}"
        );
        let stem = Path::new(&path)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or_default();
        assert_eq!(
            name, stem,
            "[[test]] name should match its file stem for greppability"
        );
    }
}

/// `[[bench]]` blocks are name-only (the files live in the default
/// `benches/` dir, where auto-discovery works), so the audit matches
/// names against file stems in both directions.
fn registered_benches(manifest: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut in_bench = false;
    for line in manifest.lines() {
        let line = line.trim();
        if line.starts_with('[') {
            in_bench = line == "[[bench]]";
            continue;
        }
        if !in_bench {
            continue;
        }
        if let Some((key, value)) = line.split_once('=') {
            if key.trim() == "name" {
                out.insert(value.trim().trim_matches('"').to_string());
            }
        }
    }
    out
}

#[test]
fn every_bench_file_matches_a_manifest_block_and_vice_versa() {
    let root = repo_root();
    let manifest = std::fs::read_to_string(root.join("Cargo.toml")).expect("read Cargo.toml");
    let registered = registered_benches(&manifest);
    assert!(
        registered.len() >= 9,
        "expected the known [[bench]] blocks, parsed only {}",
        registered.len()
    );

    let mut on_disk: BTreeSet<String> = BTreeSet::new();
    for entry in std::fs::read_dir(root.join("benches")).expect("read benches/") {
        let entry = entry.expect("dir entry");
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(stem) = name.strip_suffix(".rs") {
            on_disk.insert(stem.to_string());
        }
    }

    let dead: Vec<&String> = on_disk.difference(&registered).collect();
    assert!(
        dead.is_empty(),
        "bench files with no [[bench]] block in Cargo.toml (they never build): {dead:?}"
    );
    let phantom: Vec<&String> = registered.difference(&on_disk).collect();
    assert!(
        phantom.is_empty(),
        "[[bench]] blocks naming no benches/*.rs file: {phantom:?}"
    );
}

/// Lint-registry audit: diagnostic codes are unique, well-shaped
/// (`RLHF` + three digits), and every one of them is documented in the
/// DESIGN.md rule-catalog table — a finding a user can hit but cannot
/// look up is a doc bug.
#[test]
fn lint_codes_are_unique_well_shaped_and_documented() {
    use rlhf_mem::lint::CODES;

    let mut seen = BTreeSet::new();
    for info in CODES {
        assert!(
            seen.insert(info.code),
            "duplicate diagnostic code {}",
            info.code
        );
        let digits = info.code.strip_prefix("RLHF").unwrap_or_else(|| {
            panic!("code '{}' does not start with RLHF", info.code)
        });
        assert!(
            digits.len() == 3 && digits.bytes().all(|b| b.is_ascii_digit()),
            "code '{}' is not RLHF + three digits",
            info.code
        );
        assert!(
            !info.summary.is_empty(),
            "code {} has an empty summary",
            info.code
        );
    }

    let design =
        std::fs::read_to_string(repo_root().join("DESIGN.md")).expect("read DESIGN.md");
    let undocumented: Vec<&str> = CODES
        .iter()
        .map(|c| c.code)
        .filter(|code| !design.contains(code))
        .collect();
    assert!(
        undocumented.is_empty(),
        "diagnostic codes missing from the DESIGN.md rule catalog: {undocumented:?}"
    );
}
