//! Meta-test: every integration test file is actually registered.
//!
//! Because the crate lays its sources out under `rust/` instead of the
//! default `tests/`, Cargo's auto-discovery is off and every integration
//! test needs an explicit `[[test]]` block in `Cargo.toml`. A file that
//! is added without one compiles never and fails never — PR 4 found
//! `cluster_determinism.rs` silently dead this way. This test walks both
//! directions: every `rust/tests/*.rs` file has a `[[test]]` entry, and
//! every `[[test]]` entry points at an existing file.

use std::collections::BTreeSet;
use std::path::Path;

/// Minimal extraction of `[[test]]` blocks from Cargo.toml: collects the
/// `name`/`path` pairs that follow each `[[test]]` header (the manifest
/// is committed alongside this file, so the dependency-free parse only
/// has to handle the style used there: one `key = "value"` per line).
fn registered_tests(manifest: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    let mut in_test = false;
    let mut name: Option<String> = None;
    let mut path: Option<String> = None;
    let mut flush = |name: &mut Option<String>, path: &mut Option<String>| {
        if name.is_some() || path.is_some() {
            out.push((
                name.take().unwrap_or_default(),
                path.take().unwrap_or_default(),
            ));
        }
    };
    for line in manifest.lines() {
        let line = line.trim();
        if line.starts_with('[') {
            if in_test {
                flush(&mut name, &mut path);
            }
            in_test = line == "[[test]]";
            continue;
        }
        if !in_test {
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            continue;
        };
        let value = value.trim().trim_matches('"').to_string();
        match key.trim() {
            "name" => name = Some(value),
            "path" => path = Some(value),
            _ => {}
        }
    }
    if in_test {
        flush(&mut name, &mut path);
    }
    out
}

fn repo_root() -> std::path::PathBuf {
    // CARGO_MANIFEST_DIR is the repo root (Cargo.toml lives there).
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn every_test_file_is_registered_in_the_manifest() {
    let root = repo_root();
    let manifest = std::fs::read_to_string(root.join("Cargo.toml")).expect("read Cargo.toml");
    let registered: BTreeSet<String> = registered_tests(&manifest)
        .into_iter()
        .map(|(_, path)| path)
        .collect();

    let mut on_disk: BTreeSet<String> = BTreeSet::new();
    for entry in std::fs::read_dir(root.join("rust/tests")).expect("read rust/tests") {
        let entry = entry.expect("dir entry");
        if !entry.file_type().expect("file type").is_file() {
            continue; // support/ dirs hold shared helpers, not test roots
        }
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.ends_with(".rs") {
            on_disk.insert(format!("rust/tests/{name}"));
        }
    }

    let dead: Vec<&String> = on_disk.difference(&registered).collect();
    assert!(
        dead.is_empty(),
        "test files with no [[test]] block in Cargo.toml (they never run): {dead:?}"
    );
    assert!(
        on_disk.contains("rust/tests/registration_audit.rs"),
        "the audit must see itself — the directory scan is broken"
    );
}

#[test]
fn every_manifest_entry_points_at_a_real_file() {
    let root = repo_root();
    let manifest = std::fs::read_to_string(root.join("Cargo.toml")).expect("read Cargo.toml");
    let entries = registered_tests(&manifest);
    assert!(
        entries.len() >= 11,
        "expected the known [[test]] blocks, parsed only {}",
        entries.len()
    );
    for (name, path) in entries {
        assert!(!name.is_empty(), "[[test]] block without a name (path {path})");
        assert!(
            !path.is_empty(),
            "[[test]] '{name}' has no explicit path — auto-discovery is off \
             for this layout, so it would never run"
        );
        assert!(
            Path::new(&root.join(&path)).is_file(),
            "[[test]] '{name}' points at missing file {path}"
        );
        let stem = Path::new(&path)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or_default();
        assert_eq!(
            name, stem,
            "[[test]] name should match its file stem for greppability"
        );
    }
}
