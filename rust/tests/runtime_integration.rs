//! Integration tests of the PJRT runtime + real PPO loop. The whole file
//! is gated on the `pjrt` feature (the runtime needs the `xla` FFI crate,
//! which the offline build does not carry). With the feature on, they
//! additionally require `make artifacts` to have run; they are skipped
//! (pass trivially) when the artifacts are absent so `cargo test` stays
//! green on a fresh checkout.
#![cfg(feature = "pjrt")]

use rlhf_mem::rlhf::real::{PpoConfig, RealPpoTrainer};
use rlhf_mem::runtime::{KernelVariant, RlhfEngine};

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&format!("{dir}/opt-nano.manifest.json")).exists() {
        Some(dir)
    } else {
        eprintln!("artifacts missing; skipping runtime integration test");
        None
    }
}

#[test]
fn engine_loads_and_scores() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = RlhfEngine::load(&dir, "opt-nano", KernelVariant::Jnp).unwrap();
    let m = &engine.manifest;
    assert_eq!(m.arch, "opt-nano");
    let tokens = vec![1i32; m.batch * m.max_seq];
    let (lp, values) = engine.score(&engine.params, &tokens).unwrap();
    assert_eq!(lp.len(), m.batch * (m.max_seq - 1));
    assert_eq!(values.len(), m.batch * m.max_seq);
    // Logprobs must be valid (≤ 0, finite).
    assert!(lp.iter().all(|&x| x.is_finite() && x <= 1e-5));
}

#[test]
fn pallas_and_jnp_artifacts_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let jnp = RlhfEngine::load(&dir, "opt-nano", KernelVariant::Jnp).unwrap();
    if jnp.manifest.artifact_file("score.pallas").is_none() {
        eprintln!("pallas artifact not in manifest; skipping");
        return;
    }
    let pallas = RlhfEngine::load(&dir, "opt-nano", KernelVariant::Pallas).unwrap();
    let m = &jnp.manifest;
    let tokens: Vec<i32> = (0..m.batch * m.max_seq)
        .map(|i| (i % m.vocab) as i32)
        .collect();
    let (lp1, v1) = jnp.score(&jnp.params, &tokens).unwrap();
    let (lp2, v2) = pallas.score(&pallas.params, &tokens).unwrap();
    for (a, b) in lp1.iter().zip(&lp2) {
        assert!((a - b).abs() < 3e-3, "logprob mismatch {a} vs {b}");
    }
    for (a, b) in v1.iter().zip(&v2) {
        assert!((a - b).abs() < 3e-3, "value mismatch {a} vs {b}");
    }
}

#[test]
fn decode_is_consistent_with_score() {
    // Teacher-forcing the decode path over a fixed sequence must give the
    // same next-token distribution as the full scoring pass.
    let Some(dir) = artifacts_dir() else { return };
    let engine = RlhfEngine::load(&dir, "opt-nano", KernelVariant::Jnp).unwrap();
    let m = engine.manifest.clone();
    let (b, s) = (m.batch, m.max_seq);
    let tokens: Vec<i32> = (0..b * s).map(|i| ((i * 31 + 7) % m.vocab) as i32).collect();

    let (score_lp, _) = engine.score(&engine.params, &tokens).unwrap();

    let mut kv = engine.init_kv().unwrap();
    // Feed every position sequentially (the KV cache must see the full
    // prefix); check the distribution at a few of them.
    for pos in 0usize..12 {
        let col: Vec<i32> = (0..b).map(|bi| tokens[bi * s + pos]).collect();
        let (logits, kv_new) = engine.decode(&kv, &col, pos as i32).unwrap();
        kv = kv_new;
        if !matches!(pos, 0 | 3 | 10) {
            continue;
        }
        // softmax -> logprob of the actual next token must match score.
        for bi in 0..b {
            let row = &logits[bi * m.vocab..(bi + 1) * m.vocab];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let logsum = row.iter().map(|&l| ((l - max) as f64).exp()).sum::<f64>().ln() as f32 + max;
            let next = tokens[bi * s + pos + 1] as usize;
            let lp = row[next] - logsum;
            let expect = score_lp[bi * (s - 1) + pos];
            assert!(
                (lp - expect).abs() < 3e-3,
                "pos {pos} b {bi}: {lp} vs {expect}"
            );
        }
    }
}

#[test]
fn one_ppo_iteration_runs_and_is_finite() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = RlhfEngine::load(&dir, "opt-nano", KernelVariant::Jnp).unwrap();
    let mut trainer = RealPpoTrainer::new(engine, PpoConfig::default());
    let s = trainer.step().unwrap();
    assert!(s.mean_reward.is_finite());
    assert!(s.policy_loss.is_finite());
    assert!(s.value_loss.is_finite());
    assert!(s.entropy > 0.0, "entropy of a fresh policy must be positive");
    assert!(s.mean_reward >= -1.0 && s.mean_reward <= 1.0);
}

#[test]
fn reward_function_shape() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = RlhfEngine::load(&dir, "opt-nano", KernelVariant::Jnp).unwrap();
    let trainer = RealPpoTrainer::new(engine, PpoConfig::default());
    // All-preferred response -> +1; none-preferred -> -1.
    assert_eq!(trainer.reward(&[3, 10, 17, 24]), 1.0);
    assert_eq!(trainer.reward(&[0, 1, 2, 4]), -1.0);
    assert_eq!(trainer.reward(&[]), 0.0);
}
