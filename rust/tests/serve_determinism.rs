//! Serve-subsystem gates (DESIGN.md §18): the grid runner and the serve
//! planner must produce byte-identical artifacts for any `--jobs`, the
//! paged discipline must never fragment worse than best-fit reservation
//! under concurrency pressure, seeded streams must replay exactly, and
//! artifact readers must reject foreign or missing schema headers.

use rlhf_mem::planner::Budget;
use rlhf_mem::rlhf::GpuSpec;
use rlhf_mem::serve::{plan_serve, run_cells, ServeSpec};
use rlhf_mem::util::schema;

/// A small but non-trivial grid: (2 page sizes + best-fit) × 2 ceilings.
fn spec() -> ServeSpec {
    ServeSpec {
        requests: 32,
        arrival_rps: 40.0,
        prompt_len: 128,
        prompt_jitter: 32,
        max_new: 64,
        response_jitter: 16,
        page_tokens: vec![16, 32],
        max_concurrency: vec![4, 8],
        ..ServeSpec::default()
    }
}

#[test]
fn grid_artifact_is_jobs_invariant_and_versioned() {
    let cells = spec().cells("rtx3090", GpuSpec::rtx3090()).unwrap();
    assert_eq!(cells.len(), 6, "(paged×2 + best-fit) × 2 concurrencies");
    let a = run_cells(&cells, 1);
    let b = run_cells(&cells, 4);
    assert_eq!(
        a.jsonl_with_telemetry(),
        b.jsonl_with_telemetry(),
        "serve artifact must not depend on worker count"
    );
    schema::check_jsonl("serve", &a.jsonl()).unwrap();
    assert_eq!(a.jsonl().lines().count(), cells.len() + 1);
}

#[test]
fn planner_artifact_is_jobs_invariant_and_recommends() {
    let mut budget = Budget::rtx3090_table1();
    budget.serve = Some(spec());
    let a = plan_serve(&budget, 1).unwrap();
    let b = plan_serve(&budget, 4).unwrap();
    assert_eq!(a.jsonl_with_telemetry(), b.jsonl_with_telemetry());
    schema::check_jsonl("serve", &a.jsonl()).unwrap();
    // 8 GiB of KV against ≤ 8 concurrent short requests: nothing drops,
    // so the planner must land on a recommendation.
    let rec = a.recommendation().expect("grid has a feasible cell");
    assert_eq!(rec.outcome.failed, 0);
}

#[test]
fn paged_never_fragments_worse_than_best_fit_under_pressure() {
    // Pile up requests behind a high concurrency ceiling with large
    // response budgets: best-fit reserves prompt+max_new per admission
    // while pages waste at most page_tokens-1 slots per active request.
    let spec = ServeSpec {
        requests: 64,
        arrival_rps: 200.0,
        prompt_len: 128,
        prompt_jitter: 32,
        max_new: 128,
        response_jitter: 16,
        page_tokens: vec![8],
        max_concurrency: vec![16],
        ..ServeSpec::default()
    };
    let cells = spec.cells("rtx3090", GpuSpec::rtx3090()).unwrap();
    let report = run_cells(&cells, 2);
    let frag_of = |name: &str| -> u64 {
        report
            .cells
            .iter()
            .filter(|c| c.discipline == name)
            .map(rlhf_mem::serve::ServeCellResult::kv_frag_bytes)
            .max()
            .expect("discipline present in grid")
    };
    let (paged, best_fit) = (frag_of("paged"), frag_of("best-fit"));
    assert!(
        paged <= best_fit,
        "paged frag {paged} B must not exceed best-fit frag {best_fit} B"
    );
    assert!(best_fit > 0, "worst-case reservation must strand KV at peak");
}

#[test]
fn seeded_stream_replays_byte_identically_and_seed_matters() {
    let cells = spec().cells("rtx3090", GpuSpec::rtx3090()).unwrap();
    let a = run_cells(&cells, 2);
    let b = run_cells(&cells, 2);
    assert_eq!(a.jsonl(), b.jsonl(), "same seed must replay exactly");

    let reseeded = ServeSpec { seed: 1, ..spec() };
    let cells2 = reseeded.cells("rtx3090", GpuSpec::rtx3090()).unwrap();
    let c = run_cells(&cells2, 2);
    assert_ne!(
        a.jsonl(),
        c.jsonl(),
        "a different stream seed must change the artifact"
    );
}

#[test]
fn serve_reader_rejects_foreign_and_missing_schemas() {
    // A training-sweep artifact handed to the serve reader fails loud,
    // naming both the found and the expected tag.
    let sweep = format!("{}\n{{\"cell\":0}}\n", schema::header_line("sweep"));
    let err = schema::check_jsonl("serve", &sweep).unwrap_err();
    assert!(err.contains("rlhf-mem-sweep-v1"), "{err}");
    assert!(err.contains("rlhf-mem-serve-v1"), "{err}");

    // Headerless (pre-versioning) and empty artifacts are both actionable.
    let err = schema::check_jsonl("serve", "{\"cell\":0}\n").unwrap_err();
    assert!(err.contains("no schema header"), "{err}");
    let err = schema::check_jsonl("serve", "").unwrap_err();
    assert!(err.contains("empty artifact"), "{err}");

    // A future format version is rejected rather than mis-parsed.
    let future = "{\"schema\":\"rlhf-mem-serve-v9\"}\n";
    let err = schema::check_jsonl("serve", future).unwrap_err();
    assert!(err.contains("rlhf-mem-serve-v9"), "{err}");
}
