//! Golden pins for the PhaseProgram refactor.
//!
//! Pre-refactor, `Emitter::run` hardcoded the PPO pipeline as
//! `ScenarioMode` match arms. The refactor made the pipeline data — a
//! compiled [`PhaseProgram`] — and the emitter an interpreter. These
//! tests preserve the *old* match-arm pipelines verbatim as hand-written
//! oracle node lists and assert that compilation reproduces them exactly
//! and the interpreter emits **op-for-op identical** traces over them.
//!
//! Scope: this pins exactly the surface the refactor changed — pipeline
//! *selection* (which phases run, in what order, gated how). The emitter
//! bodies themselves are shared between both runs, so a regression inside
//! a body would move both traces together; numeric drift there is gated
//! separately by the allocator/paper tests (`table1 --compare-paper`,
//! `rust/tests/integration.rs`) and the per-module sim tests.
//!
//! They also pin the algorithm axis's headline: critic-free (GRPO/ReMax)
//! and reference-only (DPO) pipelines reserve less than PPO for the same
//! model set.

use rlhf_mem::experiment::{run_scenario, RTX3090_HBM};
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::models::{Role, RoleSet};
use rlhf_mem::rlhf::program::{
    AdvantageKind, Algo, ExpTensor, LossKind, PhaseBody, PhaseNode, PhaseProgram,
};
use rlhf_mem::rlhf::sim::{
    build_trace, build_trace_with_program, ScenarioMode, SimScenario,
};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::trace::PhaseKind;

/// The pre-refactor `Emitter::run` PPO pipeline, written out by hand:
/// exactly the phases the old `ScenarioMode` match arms ran, gated on
/// the same `hosts()` checks, in the same order.
fn legacy_ppo_program(scn: &SimScenario) -> PhaseProgram {
    assert_eq!(scn.algo, Algo::Ppo, "the legacy emitter was PPO-only");
    let hosts = |r: Role| scn.roles.contains(r);
    let mark = |kind: PhaseKind, requires: RoleSet, body: PhaseBody| PhaseNode {
        kind: Some(kind),
        requires,
        body,
    };
    let silent = |requires: RoleSet, body: PhaseBody| PhaseNode {
        kind: None,
        requires,
        body,
    };
    let infer = |role: Role, kind: PhaseKind| {
        mark(
            kind,
            RoleSet::of(&[role]),
            PhaseBody::Infer { role, pairs: false },
        )
    };
    let ppo_precollected = vec![
        ExpTensor::SeqTokens,
        ExpTensor::Mask,
        ExpTensor::PerTokenF32, // old logprobs
        ExpTensor::PerTokenF32, // ref logprobs
        ExpTensor::PerSeqF32,   // rewards
        ExpTensor::PerTokenF32, // values
        ExpTensor::PerTokenF32, // advantages
        ExpTensor::PerTokenF32, // returns
    ];
    let train_actor = mark(
        PhaseKind::TrainActor,
        RoleSet::of(&[Role::Actor]),
        PhaseBody::Train {
            role: Role::Actor,
            loss: LossKind::PpoClip,
            pairs: false,
        },
    );
    let train_critic = mark(
        PhaseKind::TrainCritic,
        RoleSet::of(&[Role::Critic]),
        PhaseBody::Train {
            role: Role::Critic,
            loss: LossKind::ValueLoss,
            pairs: false,
        },
    );

    let mut nodes: Vec<PhaseNode> = Vec::new();
    match scn.mode {
        ScenarioMode::Full => {
            if hosts(Role::Actor) {
                nodes.push(mark(
                    PhaseKind::Generation,
                    RoleSet::of(&[Role::Actor]),
                    PhaseBody::Generation {
                        greedy_baseline: false,
                    },
                ));
                nodes.push(infer(Role::Actor, PhaseKind::InferActor));
            } else {
                nodes.push(silent(
                    RoleSet::EMPTY,
                    PhaseBody::RemoteSequences {
                        greedy_baseline: false,
                    },
                ));
            }
            if hosts(Role::Reference) {
                nodes.push(infer(Role::Reference, PhaseKind::InferReference));
            }
            if hosts(Role::Reward) {
                nodes.push(infer(Role::Reward, PhaseKind::InferReward));
            }
            if hosts(Role::Critic) {
                nodes.push(infer(Role::Critic, PhaseKind::InferCritic));
            }
            if hosts(Role::Actor) || hosts(Role::Critic) {
                nodes.push(silent(
                    RoleSet::of(&[Role::Actor, Role::Critic]),
                    PhaseBody::Advantages {
                        kind: AdvantageKind::Gae,
                    },
                ));
            }
            if hosts(Role::Actor) {
                nodes.push(train_actor);
            }
            if hosts(Role::Critic) {
                nodes.push(train_critic);
            }
        }
        ScenarioMode::TrainBothPrecollected => {
            nodes.push(silent(
                RoleSet::EMPTY,
                PhaseBody::LoadExperience {
                    tensors: ppo_precollected,
                },
            ));
            if hosts(Role::Actor) {
                nodes.push(train_actor);
            }
            if hosts(Role::Critic) {
                nodes.push(train_critic);
            }
        }
        ScenarioMode::TrainActorOnly => {
            nodes.push(silent(
                RoleSet::EMPTY,
                PhaseBody::LoadExperience {
                    tensors: ppo_precollected,
                },
            ));
            if hosts(Role::Actor) {
                nodes.push(train_actor);
            }
        }
    }
    nodes.push(silent(RoleSet::EMPTY, PhaseBody::FreeExperience));
    PhaseProgram {
        algo: Algo::Ppo,
        active_roles: scn.roles,
        nodes,
    }
}

/// The PPO scenario matrix the golden pin covers: both frameworks, the
/// strategy extremes, every mode, a jittering model set, a placement
/// role-subset, time-sharing, and a non-zero rank.
fn golden_scenarios() -> Vec<(String, SimScenario)> {
    let mut out: Vec<(String, SimScenario)> = Vec::new();
    for (label, strategy) in [
        ("none", StrategyConfig::none()),
        ("zero3", StrategyConfig::zero3()),
        ("all", StrategyConfig::all_enabled()),
    ] {
        let mut scn = SimScenario::deepspeed_opt(strategy, EmptyCachePolicy::AfterBoth);
        scn.steps = 2;
        out.push((format!("ds-opt/{label}"), scn));
    }
    for mode in ScenarioMode::ALL {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        scn.steps = 1;
        scn.mode = mode;
        out.push((format!("ds-opt/mode-{}", mode.name()), scn));
    }
    let mut cc = SimScenario::colossal_opt(StrategyConfig::zero3(), EmptyCachePolicy::AfterInference);
    cc.steps = 2;
    out.push(("cc-opt/zero3-jitter".to_string(), cc));
    let mut gpt2 = SimScenario::colossal_gpt2(StrategyConfig::none(), EmptyCachePolicy::Never);
    gpt2.steps = 1;
    out.push(("cc-gpt2/none".to_string(), gpt2));
    let mut scorer = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
    scorer.steps = 2;
    scorer.roles = RoleSet::of(&[Role::Reference, Role::Reward]);
    out.push(("ds-opt/scorer-gpu".to_string(), scorer));
    let mut shared = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
    shared.steps = 2;
    shared.time_shared = RoleSet::of(&[Role::Reference, Role::Reward]);
    out.push(("ds-opt/time-shared".to_string(), shared));
    let mut rank3 = SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never);
    rank3.steps = 1;
    rank3.rank = 3;
    out.push(("ds-opt/zero3-rank3".to_string(), rank3));
    out
}

#[test]
fn compiled_ppo_programs_equal_the_legacy_pipelines() {
    for (label, scn) in golden_scenarios() {
        assert_eq!(
            PhaseProgram::compile(&scn),
            legacy_ppo_program(&scn),
            "{label}: compilation diverged from the legacy match arms"
        );
    }
}

#[test]
fn ppo_traces_are_op_for_op_identical_to_the_legacy_pipeline() {
    for (label, scn) in golden_scenarios() {
        let legacy = legacy_ppo_program(&scn);
        let compiled = build_trace(&scn);
        let oracle = build_trace_with_program(&scn, &legacy);
        assert_eq!(
            compiled.fingerprint(),
            oracle.fingerprint(),
            "{label}: fingerprints diverged"
        );
        assert_eq!(compiled.ops.len(), oracle.ops.len(), "{label}");
        // Fingerprint equality already implies this with overwhelming
        // probability; the exact comparison makes failures debuggable.
        assert!(compiled.ops == oracle.ops, "{label}: op streams diverged");
    }
}

#[test]
fn build_trace_is_deterministic() {
    let mut scn = SimScenario::colossal_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never);
    scn.steps = 2;
    for algo in Algo::ALL {
        scn.algo = algo;
        let a = build_trace(&scn).fingerprint();
        let b = build_trace(&scn).fingerprint();
        assert_eq!(a, b, "{}", algo.name());
    }
}

#[test]
fn critic_free_and_preference_algos_reserve_less_than_ppo() {
    let run = |algo: Algo| {
        let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
        scn.steps = 2;
        scn.algo = algo;
        run_scenario(&scn, RTX3090_HBM).summary
    };
    let ppo = run(Algo::Ppo);
    assert!(!ppo.oom);
    for algo in [Algo::Grpo, Algo::Dpo] {
        let s = run(algo);
        assert!(!s.oom, "{}", algo.name());
        assert!(
            s.peak_reserved < ppo.peak_reserved,
            "{} peak {} must undercut ppo {}",
            algo.name(),
            s.peak_reserved,
            ppo.peak_reserved
        );
    }
    // ReMax drops the critic too; its extra greedy rollout churns more
    // transient memory than PPO's generation but still beats PPO's
    // four-engine peak on this testbed.
    let remax = run(Algo::Remax);
    assert!(!remax.oom);
    assert!(remax.peak_reserved < ppo.peak_reserved);
}

#[test]
fn algo_traces_differ_from_ppo() {
    let mut scn = SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
    scn.steps = 1;
    let ppo = build_trace(&scn).fingerprint();
    for algo in [Algo::Grpo, Algo::Remax, Algo::Dpo] {
        scn.algo = algo;
        assert_ne!(build_trace(&scn).fingerprint(), ppo, "{}", algo.name());
    }
}
