//! The **pre-index oracle**: the seed allocator (commit `d408984`,
//! `rust/src/alloc/{pool,allocator}.rs`) preserved verbatim — linear
//! release scans and all — plus a lockstep harness that drives it and the
//! indexed [`CachingAllocator`] through identical op streams and asserts
//! their drained `(AllocEvent, StatSnapshot)` logs are element-for-element
//! identical (same fingerprint, same peaks, same fragmentation, same
//! bit-exact simulated time).
//!
//! This is the same pinning strategy `rust/tests/sim_golden.rs` used for
//! the PhaseProgram refactor: the replaced implementation lives on inside
//! the test as a hand-carried oracle, so behavior identity is *executed*,
//! not asserted from memory. Only mechanical adaptations were made to the
//! copy: crate-path imports, `BlockPool` → `OraclePool`,
//! `CachingAllocator` → `OracleAllocator`; every algorithmic line is the
//! seed's.
//!
//! Shared (via `#[path]`) by `alloc_golden.rs` and `alloc_property.rs`.

use rlhf_mem::alloc::block::{Block, BlockId, BlockSlab, BlockState, NO_BLOCK};
use rlhf_mem::alloc::config::{AllocatorConfig, PoolKind};
use rlhf_mem::alloc::driver::{SegmentId, SimDriver};
use rlhf_mem::alloc::stats::{AllocEvent, AllocStats, PhaseTag, StatSnapshot};
use rlhf_mem::alloc::{fingerprint_events, AllocError, AllocId, CachingAllocator};
use rlhf_mem::trace::{Trace, TraceOp};
use rlhf_mem::util::bytes::{round_down, round_up, KIB, MIB};
use rlhf_mem::util::fasthash::FastMap;
use rlhf_mem::util::prng::Rng;
use std::collections::BTreeSet;
use std::ops::Bound;

/// The seed free-block pool: one size-ordered set, no fully-free index —
/// `empty_cache` discovers releasable segments by scanning every entry.
#[derive(Debug, Default, Clone)]
pub struct OraclePool {
    set: BTreeSet<(u64, BlockId)>,
    cached_bytes: u64,
}

impl OraclePool {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, size: u64, id: BlockId) {
        let fresh = self.set.insert((size, id));
        debug_assert!(fresh, "block {id:?} already pooled");
        self.cached_bytes += size;
    }

    pub fn remove(&mut self, size: u64, id: BlockId) {
        let was = self.set.remove(&(size, id));
        debug_assert!(was, "block {id:?} not in pool");
        self.cached_bytes -= size;
    }

    pub fn best_fit(&self, want: u64) -> Option<(u64, BlockId)> {
        self.set
            .range((Bound::Included((want, BlockId(0))), Bound::Unbounded))
            .next()
            .copied()
    }

    pub fn best_fit_bounded(&self, want: u64, max: u64) -> Option<(u64, BlockId)> {
        // Exclusive bound: a block of exactly max_split_size is oversized
        // (PyTorch's `size >= max_split_size` test) and must be refused.
        self.best_fit(want).filter(|(sz, _)| *sz < max)
    }

    pub fn len(&self) -> usize {
        self.set.len()
    }

    pub fn is_empty(&self) -> bool {
        self.set.is_empty()
    }

    pub fn cached_bytes(&self) -> u64 {
        self.cached_bytes
    }

    pub fn iter(&self) -> impl Iterator<Item = &(u64, BlockId)> {
        self.set.iter()
    }
}

fn pool_idx(kind: PoolKind) -> usize {
    match kind {
        PoolKind::Small => 0,
        PoolKind::Large => 1,
    }
}

/// The seed `CachingAllocator`, verbatim (see module docs).
pub struct OracleAllocator {
    cfg: AllocatorConfig,
    driver: SimDriver,
    slab: BlockSlab,
    small: OraclePool,
    large: OraclePool,
    live: FastMap<u64, BlockId>,
    next_handle: u64,
    seg_heads: FastMap<SegmentId, BlockId>,
    expandable: [Option<SegmentId>; 2],
    tick: u64,
    seg_last_use: FastMap<SegmentId, u64>,
    stats: AllocStats,
    phase: PhaseTag,
    record_events: bool,
    events: Vec<(AllocEvent, StatSnapshot)>,
}

impl OracleAllocator {
    pub fn new(capacity: u64, cfg: AllocatorConfig) -> Self {
        let driver = SimDriver::new(capacity, cfg.cost.clone());
        OracleAllocator {
            cfg,
            driver,
            slab: BlockSlab::new(),
            small: OraclePool::new(),
            large: OraclePool::new(),
            live: FastMap::default(),
            next_handle: 1,
            seg_heads: FastMap::default(),
            expandable: [None, None],
            tick: 0,
            seg_last_use: FastMap::default(),
            stats: AllocStats::default(),
            phase: 0,
            record_events: false,
            events: Vec::new(),
        }
    }

    pub fn set_event_recording(&mut self, on: bool) {
        self.record_events = on;
    }

    pub fn drain_events_into(&mut self, out: &mut Vec<(AllocEvent, StatSnapshot)>) {
        out.append(&mut self.events);
    }

    pub fn set_phase(&mut self, phase: PhaseTag) {
        self.phase = phase;
    }

    pub fn stats(&self) -> &AllocStats {
        &self.stats
    }

    pub fn reserved(&self) -> u64 {
        self.driver.reserved()
    }

    pub fn allocated(&self) -> u64 {
        self.stats.allocated
    }

    pub fn time_us(&self) -> f64 {
        self.stats.time_us + self.driver.time_us
    }

    pub fn snapshot(&self) -> StatSnapshot {
        StatSnapshot {
            reserved: self.driver.reserved(),
            allocated: self.stats.allocated,
            requested: self.stats.requested,
            time_us: self.time_us(),
            phase: self.phase,
        }
    }

    fn emit(&mut self, ev: AllocEvent) {
        if self.record_events {
            let snap = self.snapshot();
            self.events.push((ev, snap));
        }
    }

    fn pool(&mut self, kind: PoolKind) -> &mut OraclePool {
        match kind {
            PoolKind::Small => &mut self.small,
            PoolKind::Large => &mut self.large,
        }
    }

    pub fn pool_cached_bytes(&self, kind: PoolKind) -> u64 {
        match kind {
            PoolKind::Small => self.small.cached_bytes(),
            PoolKind::Large => self.large.cached_bytes(),
        }
    }

    pub fn alloc(&mut self, requested: u64) -> Result<AllocId, AllocError> {
        assert!(requested > 0, "alloc(0)");
        let rounded = self.cfg.round_size(requested);
        let pool_kind = self.cfg.pool_for(rounded);

        let found = self.find_cached(rounded, pool_kind);
        let (block_id, cache_hit) = match found {
            Some(id) => (id, true),
            None => {
                let seg_block = if self.cfg.expandable_segments {
                    self.grow_expandable(rounded, pool_kind)?
                } else {
                    self.alloc_segment(rounded, pool_kind)?
                };
                (seg_block, false)
            }
        };

        let block_id = self.maybe_split(block_id, rounded, pool_kind);

        {
            let b = self.slab.get_mut(block_id);
            debug_assert_eq!(b.state, BlockState::Free);
            b.state = BlockState::Allocated;
            b.requested = requested;
        }
        let size = self.slab.get(block_id).size;
        self.stats.num_allocs += 1;
        if cache_hit {
            self.stats.num_cache_hits += 1;
        }
        self.stats.time_us += self.cfg.cost.cache_hit_us;
        self.stats.requested += requested;
        let allocated = self.stats.allocated + size;
        self.stats.sync(self.driver.reserved(), allocated);

        let handle = AllocId(self.next_handle);
        self.next_handle += 1;
        self.live.insert(handle.0, block_id);

        if self.cfg.garbage_collection_threshold.is_some() {
            self.tick += 1;
            let seg = self.slab.get(block_id).segment;
            self.seg_last_use.insert(seg, self.tick);
        }

        self.emit(AllocEvent::Alloc {
            requested,
            rounded,
            cache_hit,
        });
        Ok(handle)
    }

    fn find_cached(&mut self, rounded: u64, pool_kind: PoolKind) -> Option<BlockId> {
        let max_split = self
            .cfg
            .max_split_size
            .filter(|_| !self.cfg.expandable_segments);
        let (size, id) = {
            let pool = self.pool(pool_kind);
            match (pool_kind, max_split) {
                (PoolKind::Large, Some(max)) if rounded < max => {
                    pool.best_fit_bounded(rounded, max)
                }
                _ => pool.best_fit(rounded),
            }
        }?;
        self.pool(pool_kind).remove(size, id);
        Some(id)
    }

    fn alloc_segment(&mut self, rounded: u64, pool_kind: PoolKind) -> Result<BlockId, AllocError> {
        let seg_size = self.cfg.segment_size_for(rounded);
        self.maybe_gc(seg_size, None);
        let cached_free = self.driver.reserved() - self.stats.allocated;
        let pool_cached = self.pool_cached_bytes(pool_kind);
        let frag_sample = if pool_cached >= rounded { cached_free } else { 0 };

        let seg = match self.driver.cuda_malloc(seg_size) {
            Ok(s) => s,
            Err(_) => {
                let released = self.release_cached_segments();
                self.emit(AllocEvent::OomRetry {
                    released_bytes: released,
                });
                match self.driver.cuda_malloc(seg_size) {
                    Ok(s) => s,
                    Err(e) => {
                        return Err(AllocError::Oom(e, self.snapshot()));
                    }
                }
            }
        };
        self.note_driver_growth(seg_size, rounded, frag_sample);

        let block = Block {
            segment: seg,
            pool: pool_kind,
            offset: 0,
            size: seg_size,
            requested: 0,
            state: BlockState::Free,
            prev: NO_BLOCK,
            next: NO_BLOCK,
            origin_phase: self.phase,
            live: true,
        };
        let id = self.slab.insert(block);
        self.seg_heads.insert(seg, id);
        if self.cfg.garbage_collection_threshold.is_some() {
            self.tick += 1;
            self.seg_last_use.insert(seg, self.tick);
        }
        Ok(id)
    }

    fn note_driver_growth(&mut self, mapped_bytes: u64, rounded: u64, frag_sample: u64) {
        self.stats.last_frag_sample = frag_sample;
        if frag_sample > self.stats.max_frag_sample {
            self.stats.max_frag_sample = frag_sample;
        }
        self.stats.num_cuda_mallocs += 1;
        self.stats.reserved = self.driver.reserved();
        if self.stats.reserved > self.stats.peak_reserved {
            self.stats.peak_reserved = self.stats.reserved;
            self.stats.frag_at_peak_reserved = frag_sample;
        }
        self.emit(AllocEvent::CudaMalloc {
            segment_bytes: mapped_bytes,
            rounded,
            frag_sample,
        });
    }

    fn grow_expandable(
        &mut self,
        rounded: u64,
        pool_kind: PoolKind,
    ) -> Result<BlockId, AllocError> {
        let idx = pool_idx(pool_kind);
        let granule = self.cfg.expandable_granule();
        let mut retried = false;
        loop {
            let Some(seg) = self.expandable[idx] else {
                let block = self.alloc_segment(rounded, pool_kind)?;
                self.expandable[idx] = Some(self.slab.get(block).segment);
                return Ok(block);
            };
            let head = *self.seg_heads.get(&seg).expect("expandable segment head");
            let mut tail = head;
            while self.slab.get(tail).next != NO_BLOCK {
                tail = BlockId(self.slab.get(tail).next);
            }
            let (tail_state, tail_size) = {
                let b = self.slab.get(tail);
                (b.state, b.size)
            };
            let free_tail = if tail_state == BlockState::Free {
                tail_size
            } else {
                0
            };
            let need = rounded.saturating_sub(free_tail);
            if need == 0 {
                self.pool(pool_kind).remove(tail_size, tail);
                return Ok(tail);
            }
            let delta = round_up(need, granule);
            self.maybe_gc(delta, Some(seg));
            let cached_free = self.driver.reserved() - self.stats.allocated;
            let pool_cached = self.pool_cached_bytes(pool_kind);
            let frag_sample = if pool_cached >= rounded { cached_free } else { 0 };
            match self.driver.grow_segment(seg, delta) {
                Ok(()) => {
                    self.note_driver_growth(delta, rounded, frag_sample);
                    if tail_state == BlockState::Free {
                        self.pool(pool_kind).remove(tail_size, tail);
                        self.slab.get_mut(tail).size = tail_size + delta;
                        return Ok(tail);
                    }
                    let offset = {
                        let b = self.slab.get(tail);
                        b.offset + b.size
                    };
                    let grown = Block {
                        segment: seg,
                        pool: pool_kind,
                        offset,
                        size: delta,
                        requested: 0,
                        state: BlockState::Free,
                        prev: tail.0,
                        next: NO_BLOCK,
                        origin_phase: self.phase,
                        live: true,
                    };
                    let grown_id = self.slab.insert(grown);
                    self.slab.get_mut(tail).next = grown_id.0;
                    return Ok(grown_id);
                }
                Err(e) => {
                    if retried {
                        return Err(AllocError::Oom(e, self.snapshot()));
                    }
                    retried = true;
                    let released = self.release_cached_segments();
                    self.emit(AllocEvent::OomRetry {
                        released_bytes: released,
                    });
                }
            }
        }
    }

    fn maybe_gc(&mut self, incoming: u64, keep: Option<SegmentId>) {
        let Some(threshold) = self.cfg.garbage_collection_threshold else {
            return;
        };
        let target = (threshold * self.driver.capacity() as f64) as u64;
        if self.driver.reserved() + incoming <= target {
            return;
        }
        // The seed's linear pass: every segment head is inspected.
        let mut candidates: Vec<(u64, u32, BlockId, u64, PoolKind)> = Vec::new();
        for (&seg, &head) in &self.seg_heads {
            if keep == Some(seg) {
                continue;
            }
            let b = self.slab.get(head);
            if b.state == BlockState::Free && b.next == NO_BLOCK {
                let age = self.seg_last_use.get(&seg).copied().unwrap_or(0);
                candidates.push((age, seg.0, head, b.size, b.pool));
            }
        }
        candidates.sort_unstable_by_key(|&(age, seg, ..)| (age, seg));
        let mut released = 0u64;
        let mut segments = 0u64;
        for (_, seg_raw, head, size, pool_kind) in candidates {
            if self.driver.reserved() + incoming <= target {
                break;
            }
            self.release_full_segment(SegmentId(seg_raw), head, size, pool_kind);
            released += size;
            segments += 1;
        }
        if segments > 0 {
            self.stats.num_gc_passes += 1;
            self.stats.gc_reclaimed += released;
            self.stats.sync(self.driver.reserved(), self.stats.allocated);
            self.emit(AllocEvent::GcReclaim {
                segments,
                bytes: released,
            });
        }
    }

    fn release_full_segment(
        &mut self,
        seg: SegmentId,
        head: BlockId,
        size: u64,
        pool_kind: PoolKind,
    ) {
        self.pool(pool_kind).remove(size, head);
        self.slab.remove(head);
        self.seg_heads.remove(&seg);
        self.seg_last_use.remove(&seg);
        for slot in self.expandable.iter_mut() {
            if *slot == Some(seg) {
                *slot = None;
            }
        }
        self.driver.cuda_free(seg);
        self.stats.num_cuda_frees += 1;
    }

    fn maybe_split(&mut self, block_id: BlockId, rounded: u64, pool_kind: PoolKind) -> BlockId {
        let (size, offset, seg, next, origin_phase) = {
            let b = self.slab.get(block_id);
            (b.size, b.offset, b.segment, b.next, b.origin_phase)
        };
        debug_assert!(size >= rounded);
        if !self.cfg.should_split(size, rounded, pool_kind) {
            return block_id;
        }
        let rem = Block {
            segment: seg,
            pool: pool_kind,
            offset: offset + rounded,
            size: size - rounded,
            requested: 0,
            state: BlockState::Free,
            prev: block_id.0,
            next,
            origin_phase,
            live: true,
        };
        let rem_id = self.slab.insert(rem);
        if next != NO_BLOCK {
            self.slab.get_mut(BlockId(next)).prev = rem_id.0;
        }
        {
            let b = self.slab.get_mut(block_id);
            b.size = rounded;
            b.next = rem_id.0;
        }
        let rem_size = size - rounded;
        self.pool(pool_kind).insert(rem_size, rem_id);
        block_id
    }

    pub fn free(&mut self, handle: AllocId) {
        let block_id = self
            .live
            .remove(&handle.0)
            .unwrap_or_else(|| panic!("free of unknown handle {handle:?}"));
        let (size, requested, pool_kind) = {
            let b = self.slab.get_mut(block_id);
            debug_assert_eq!(b.state, BlockState::Allocated);
            b.state = BlockState::Free;
            let r = b.requested;
            b.requested = 0;
            (b.size, r, b.pool)
        };
        self.stats.num_frees += 1;
        self.stats.time_us += self.cfg.cost.pool_free_us;
        self.stats.requested -= requested;
        let allocated = self.stats.allocated - size;
        self.stats.sync(self.driver.reserved(), allocated);

        let merged = self.coalesce(block_id, pool_kind);
        let merged_size = self.slab.get(merged).size;
        self.pool(pool_kind).insert(merged_size, merged);

        self.emit(AllocEvent::Free { size });
    }

    fn coalesce(&mut self, block_id: BlockId, pool_kind: PoolKind) -> BlockId {
        let mut cur = block_id;

        let prev = self.slab.get(cur).prev;
        if prev != NO_BLOCK {
            let prev_id = BlockId(prev);
            if self.slab.get(prev_id).state == BlockState::Free {
                let prev_size = self.slab.get(prev_id).size;
                self.pool(pool_kind).remove(prev_size, prev_id);
                let (cur_size, cur_next) = {
                    let c = self.slab.get(cur);
                    (c.size, c.next)
                };
                {
                    let p = self.slab.get_mut(prev_id);
                    p.size += cur_size;
                    p.next = cur_next;
                }
                if cur_next != NO_BLOCK {
                    self.slab.get_mut(BlockId(cur_next)).prev = prev_id.0;
                }
                self.slab.remove(cur);
                cur = prev_id;
            }
        }

        let next = self.slab.get(cur).next;
        if next != NO_BLOCK {
            let next_id = BlockId(next);
            if self.slab.get(next_id).state == BlockState::Free {
                let next_size = self.slab.get(next_id).size;
                self.pool(pool_kind).remove(next_size, next_id);
                let next_next = self.slab.get(next_id).next;
                {
                    let c = self.slab.get_mut(cur);
                    c.size += next_size;
                    c.next = next_next;
                }
                if next_next != NO_BLOCK {
                    self.slab.get_mut(BlockId(next_next)).prev = cur.0;
                }
                self.slab.remove(next_id);
            }
        }
        cur
    }

    /// The seed's linear release scan: every pooled block is visited to
    /// find the fully-free segments.
    fn release_cached_segments(&mut self) -> u64 {
        let mut released = 0u64;
        for pool_kind in [PoolKind::Small, PoolKind::Large] {
            let candidates: Vec<(u64, BlockId)> =
                self.pool(pool_kind).iter().copied().collect();
            for (size, id) in candidates {
                let (seg, offset) = {
                    let b = self.slab.get(id);
                    (b.segment, b.offset)
                };
                let seg_size = self.driver.segment_size(seg);
                if offset == 0 && size == seg_size {
                    self.release_full_segment(seg, id, size, pool_kind);
                    released += seg_size;
                    self.emit(AllocEvent::CudaFree {
                        segment_bytes: seg_size,
                    });
                }
            }
        }
        if self.cfg.expandable_segments {
            released += self.shrink_expandable_tails();
        }
        if released > 0 {
            self.stats.sync(self.driver.reserved(), self.stats.allocated);
        }
        released
    }

    fn shrink_expandable_tails(&mut self) -> u64 {
        let granule = self.cfg.expandable_granule();
        let mut released = 0u64;
        for slot in self.expandable {
            let Some(seg) = slot else {
                continue;
            };
            let head = *self.seg_heads.get(&seg).expect("expandable segment head");
            let mut tail = head;
            while self.slab.get(tail).next != NO_BLOCK {
                tail = BlockId(self.slab.get(tail).next);
            }
            let (state, size, offset, prev, pool_kind) = {
                let b = self.slab.get(tail);
                (b.state, b.size, b.offset, b.prev, b.pool)
            };
            if state != BlockState::Free || offset == 0 {
                continue;
            }
            let cut = round_down(size, granule);
            if cut == 0 {
                continue;
            }
            self.pool(pool_kind).remove(size, tail);
            if cut == size {
                self.slab.get_mut(BlockId(prev)).next = NO_BLOCK;
                self.slab.remove(tail);
            } else {
                self.slab.get_mut(tail).size = size - cut;
                self.pool(pool_kind).insert(size - cut, tail);
            }
            self.driver.shrink_segment(seg, cut);
            self.stats.shrunk_bytes += cut;
            self.emit(AllocEvent::SegmentShrink { bytes: cut });
            released += cut;
        }
        released
    }

    pub fn empty_cache(&mut self) -> u64 {
        self.stats.num_empty_cache += 1;
        self.stats.time_us += self.cfg.cost.empty_cache_base_us;
        let before_segments = self.driver.live_segments() as u64;
        let released = self.release_cached_segments();
        let segs = before_segments - self.driver.live_segments() as u64;
        self.emit(AllocEvent::EmptyCache {
            segments: segs,
            bytes: released,
        });
        released
    }

    pub fn live_allocs(&self) -> usize {
        self.live.len()
    }

    pub fn live_segments(&self) -> usize {
        self.driver.live_segments()
    }

    /// The seed's O(everything) invariant check, minus the (new) fully-
    /// free-index clause it predates.
    pub fn validate(&self) -> Result<(), String> {
        let mut total_alloc = 0u64;
        let mut total_free = 0u64;
        let mut seg_bytes = 0u64;
        let mut free_blocks: Vec<(u64, BlockId)> = Vec::new();
        for (&seg, &head) in &self.seg_heads {
            let seg_size = self.driver.segment_size(seg);
            seg_bytes += seg_size;
            let mut cursor = head;
            let mut expect_offset = 0u64;
            let mut prev_state: Option<BlockState> = None;
            let mut prev_id = NO_BLOCK;
            loop {
                let b = self.slab.get(cursor);
                if b.segment != seg {
                    return Err(format!("block {cursor:?} in wrong segment"));
                }
                if b.offset != expect_offset {
                    return Err(format!(
                        "segment {seg:?}: expected offset {expect_offset}, got {}",
                        b.offset
                    ));
                }
                if b.prev != prev_id {
                    return Err(format!("block {cursor:?} has broken prev link"));
                }
                if b.state == BlockState::Free && prev_state == Some(BlockState::Free) {
                    return Err(format!(
                        "segment {seg:?}: adjacent free blocks (coalescing broken)"
                    ));
                }
                match b.state {
                    BlockState::Allocated => total_alloc += b.size,
                    BlockState::Free => {
                        total_free += b.size;
                        free_blocks.push((b.size, cursor));
                    }
                }
                expect_offset += b.size;
                prev_state = Some(b.state);
                prev_id = cursor.0;
                if b.next == NO_BLOCK {
                    break;
                }
                cursor = BlockId(b.next);
            }
            if expect_offset != seg_size {
                return Err(format!(
                    "segment {seg:?}: chain covers {expect_offset} of {seg_size} bytes"
                ));
            }
        }
        if seg_bytes != self.driver.reserved() {
            return Err(format!(
                "segment bytes {seg_bytes} != driver reserved {}",
                self.driver.reserved()
            ));
        }
        if total_alloc != self.stats.allocated {
            return Err(format!(
                "chain allocated {total_alloc} != stats.allocated {}",
                self.stats.allocated
            ));
        }
        if total_alloc + total_free != seg_bytes {
            return Err("allocated + free != reserved".to_string());
        }
        let pooled: u64 = self.small.cached_bytes() + self.large.cached_bytes();
        if pooled != total_free {
            return Err(format!(
                "pool bytes {pooled} != chain free bytes {total_free}"
            ));
        }
        let pool_count = self.small.len() + self.large.len();
        if pool_count != free_blocks.len() {
            return Err(format!(
                "pool count {pool_count} != free block count {}",
                free_blocks.len()
            ));
        }
        for (&h, &bid) in &self.live {
            let b = self.slab.get(bid);
            if b.state != BlockState::Allocated {
                return Err(format!("handle {h} points at non-allocated block"));
            }
        }
        if self.slab.len_live() != free_blocks.len() + self.live.len() {
            return Err(format!(
                "slab live {} != free {} + allocated {}",
                self.slab.len_live(),
                free_blocks.len(),
                self.live.len()
            ));
        }
        self.cfg.check()?;
        if self.cfg.garbage_collection_threshold.is_none() && self.stats.num_gc_passes != 0 {
            return Err("gc pass recorded without garbage_collection_threshold".to_string());
        }
        if self.cfg.expandable_segments {
            for (&seg, &head) in &self.seg_heads {
                let pool = self.slab.get(head).pool;
                if self.expandable[pool_idx(pool)] != Some(seg) {
                    return Err(format!(
                        "segment {seg:?} is not the registered expandable segment of the {} pool",
                        pool.name()
                    ));
                }
            }
            for (idx, slot) in self.expandable.iter().enumerate() {
                if let Some(seg) = slot {
                    if !self.seg_heads.contains_key(seg) {
                        return Err(format!(
                            "expandable slot {idx} points at dead segment {seg:?}"
                        ));
                    }
                }
            }
        } else if self.expandable.iter().any(|s| s.is_some()) {
            return Err("expandable segment registered without the knob".to_string());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Lockstep equivalence harness
// ---------------------------------------------------------------------------

/// Outcome of one lockstep drive, for callers that want to pin or log it.
pub struct Equivalence {
    /// Shared fingerprint of the (identical) event logs.
    pub fingerprint: u64,
    /// Events both allocators emitted.
    pub events: usize,
}

/// Compare the logs' unchecked suffix element-for-element, panicking at
/// the first divergence with just that event (not the whole log).
fn check_new_events(
    label: &str,
    at: &str,
    checked: &mut usize,
    log_a: &[(AllocEvent, StatSnapshot)],
    log_o: &[(AllocEvent, StatSnapshot)],
) {
    assert_eq!(
        log_a.len(),
        log_o.len(),
        "[{label}] {at}: event-count divergence"
    );
    while *checked < log_a.len() {
        let i = *checked;
        assert!(
            log_a[i] == log_o[i],
            "[{label}] {at}: event {i} diverged\n  indexed: {:?}\n  oracle:  {:?}",
            log_a[i],
            log_o[i]
        );
        *checked += 1;
    }
}

/// Final cross-checks once a drive completes: identical logs (already
/// verified element-wise), identical fingerprints, identical stats, both
/// `validate()` clean.
fn finish(
    label: &str,
    a: &CachingAllocator,
    o: &OracleAllocator,
    log_a: &[(AllocEvent, StatSnapshot)],
    log_o: &[(AllocEvent, StatSnapshot)],
) -> Equivalence {
    let fa = fingerprint_events(log_a);
    let fo = fingerprint_events(log_o);
    assert_eq!(fa, fo, "[{label}] event-log fingerprints diverged");
    let (sa, so) = (a.stats(), o.stats());
    assert_eq!(sa.peak_reserved, so.peak_reserved, "[{label}] peak_reserved");
    assert_eq!(sa.peak_allocated, so.peak_allocated, "[{label}] peak_allocated");
    assert_eq!(sa.max_frag_sample, so.max_frag_sample, "[{label}] max_frag_sample");
    assert_eq!(
        sa.frag_at_peak_reserved, so.frag_at_peak_reserved,
        "[{label}] frag_at_peak_reserved"
    );
    assert_eq!(sa.num_allocs, so.num_allocs, "[{label}] num_allocs");
    assert_eq!(sa.num_cache_hits, so.num_cache_hits, "[{label}] num_cache_hits");
    assert_eq!(sa.num_cuda_mallocs, so.num_cuda_mallocs, "[{label}] num_cuda_mallocs");
    assert_eq!(sa.num_cuda_frees, so.num_cuda_frees, "[{label}] num_cuda_frees");
    assert_eq!(sa.num_gc_passes, so.num_gc_passes, "[{label}] num_gc_passes");
    assert_eq!(sa.gc_reclaimed, so.gc_reclaimed, "[{label}] gc_reclaimed");
    assert_eq!(sa.shrunk_bytes, so.shrunk_bytes, "[{label}] shrunk_bytes");
    assert_eq!(
        a.time_us().to_bits(),
        o.time_us().to_bits(),
        "[{label}] simulated time must be bit-identical"
    );
    a.validate()
        .unwrap_or_else(|e| panic!("[{label}] indexed validate: {e}"));
    o.validate()
        .unwrap_or_else(|e| panic!("[{label}] oracle validate: {e}"));
    Equivalence {
        fingerprint: fa,
        events: log_a.len(),
    }
}

/// Drive the indexed allocator and the seed oracle through one seeded
/// random op stream (the `alloc_property` recipe: mixed size classes,
/// alloc-biased, periodic `empty_cache`, phase churn, teardown to zero)
/// and assert full observational equivalence.
pub fn assert_equivalent(
    cfg: &AllocatorConfig,
    capacity: u64,
    seed: u64,
    steps: u64,
    label: &str,
) -> Equivalence {
    let mut a = CachingAllocator::new(capacity, cfg.clone());
    let mut o = OracleAllocator::new(capacity, cfg.clone());
    a.set_event_recording(true);
    o.set_event_recording(true);
    let mut rng = Rng::seeded(seed);
    let mut live_a: Vec<AllocId> = Vec::new();
    let mut live_o: Vec<AllocId> = Vec::new();
    let mut log_a: Vec<(AllocEvent, StatSnapshot)> = Vec::new();
    let mut log_o: Vec<(AllocEvent, StatSnapshot)> = Vec::new();
    let mut checked = 0usize;
    for step in 0..steps {
        if step % 61 == 60 {
            let phase = (step / 61 % 9) as u16;
            a.set_phase(phase);
            o.set_phase(phase);
        }
        if live_a.is_empty() || rng.bernoulli(0.58) {
            let class = rng.gen_range(4);
            let sz = match class {
                0 => rng.gen_range(4 * KIB) + 1,
                1 => rng.gen_range(900 * KIB) + KIB,
                2 => rng.gen_range(8 * MIB) + MIB,
                _ => rng.gen_range(48 * MIB) + 10 * MIB,
            };
            let ra = a.alloc(sz);
            let ro = o.alloc(sz);
            match (ra, ro) {
                (Ok(ha), Ok(ho)) => {
                    assert_eq!(ha, ho, "[{label}] step {step}: handle divergence");
                    live_a.push(ha);
                    live_o.push(ho);
                }
                (Err(_), Err(_)) => {}
                (ra, ro) => panic!(
                    "[{label}] step {step}: alloc({sz}) diverged: \
                     indexed ok={} vs oracle ok={}",
                    ra.is_ok(),
                    ro.is_ok()
                ),
            }
        } else {
            let i = rng.range_usize(0, live_a.len());
            a.free(live_a.swap_remove(i));
            o.free(live_o.swap_remove(i));
        }
        if step % 97 == 96 {
            assert_eq!(
                a.empty_cache(),
                o.empty_cache(),
                "[{label}] step {step}: empty_cache released different bytes"
            );
        }
        a.drain_events_into(&mut log_a);
        o.drain_events_into(&mut log_o);
        check_new_events(label, &format!("step {step}"), &mut checked, &log_a, &log_o);
    }
    for (ha, ho) in live_a.into_iter().zip(live_o) {
        a.free(ha);
        o.free(ho);
    }
    assert_eq!(
        a.empty_cache(),
        o.empty_cache(),
        "[{label}] teardown empty_cache"
    );
    a.drain_events_into(&mut log_a);
    o.drain_events_into(&mut log_o);
    check_new_events(label, "teardown", &mut checked, &log_a, &log_o);
    assert_eq!(a.reserved(), 0, "[{label}] indexed must drain to zero");
    assert_eq!(o.reserved(), 0, "[{label}] oracle must drain to zero");
    finish(label, &a, &o, &log_a, &log_o)
}

/// Drive both allocators through a real RLHF trace's allocator-visible
/// ops (alloc / free / empty_cache / phase marks) and assert equivalence.
/// Replay stops at the first OOM, like `trace::replay` does — but both
/// sides must OOM on the same op.
pub fn assert_equivalent_on_trace(
    cfg: &AllocatorConfig,
    capacity: u64,
    trace: &Trace,
    label: &str,
) -> Equivalence {
    let mut a = CachingAllocator::new(capacity, cfg.clone());
    let mut o = OracleAllocator::new(capacity, cfg.clone());
    a.set_event_recording(true);
    o.set_event_recording(true);
    let mut handles_a: FastMap<u64, AllocId> = FastMap::default();
    let mut handles_o: FastMap<u64, AllocId> = FastMap::default();
    let mut log_a: Vec<(AllocEvent, StatSnapshot)> = Vec::new();
    let mut log_o: Vec<(AllocEvent, StatSnapshot)> = Vec::new();
    let mut checked = 0usize;
    for (i, op) in trace.ops.iter().enumerate() {
        match op {
            TraceOp::Alloc { handle, bytes, .. } => {
                let ra = a.alloc(*bytes);
                let ro = o.alloc(*bytes);
                match (ra, ro) {
                    (Ok(ha), Ok(ho)) => {
                        assert_eq!(ha, ho, "[{label}] op {i}: handle divergence");
                        handles_a.insert(handle.0, ha);
                        handles_o.insert(handle.0, ho);
                    }
                    (Err(_), Err(_)) => break, // same-op OOM: stop like replay()
                    (ra, ro) => panic!(
                        "[{label}] op {i}: alloc({bytes}) diverged: \
                         indexed ok={} vs oracle ok={}",
                        ra.is_ok(),
                        ro.is_ok()
                    ),
                }
            }
            TraceOp::Free { handle } => {
                let ha = handles_a.remove(&handle.0).expect("unknown trace handle");
                let ho = handles_o.remove(&handle.0).expect("unknown trace handle");
                a.free(ha);
                o.free(ho);
            }
            TraceOp::EmptyCache => {
                assert_eq!(
                    a.empty_cache(),
                    o.empty_cache(),
                    "[{label}] op {i}: empty_cache released different bytes"
                );
            }
            TraceOp::Phase(kind) => {
                a.set_phase(kind.tag());
                o.set_phase(kind.tag());
            }
            TraceOp::Compute { .. } | TraceOp::StepEnd { .. } => {}
        }
        a.drain_events_into(&mut log_a);
        o.drain_events_into(&mut log_o);
        check_new_events(label, &format!("op {i}"), &mut checked, &log_a, &log_o);
    }
    // An OOM break leaves the failed op's retry events buffered.
    a.drain_events_into(&mut log_a);
    o.drain_events_into(&mut log_o);
    check_new_events(label, "final", &mut checked, &log_a, &log_o);
    finish(label, &a, &o, &log_a, &log_o)
}
