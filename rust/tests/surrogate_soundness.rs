//! Soundness pins for the surrogate-screened planner (DESIGN.md §17).
//!
//! Two properties carry the whole two-tier design:
//!
//! 1. **Envelope bracketing** — for every in-sample cell (every group ×
//!    every fitted `steps` value, across algorithms, sharings, strategies
//!    and allocator knobs), the serialized artifact's prediction ±
//!    envelope strictly brackets the true simulated value of every
//!    target, including the per-phase peaks. The screen's exclusion
//!    logic is only sound because this holds *by construction*.
//! 2. **Frontier identity** — `plan_surrogate` emits a frontier JSONL
//!    byte-identical to the exhaustive `plan`'s, for any `--jobs`, on
//!    narrowed and full default budgets, while simulating strictly fewer
//!    candidates; artifacts that don't cover a candidate fall back to
//!    simulation rather than guessing; and a *tampered* artifact whose
//!    dominance certificates the simulated results refute makes the
//!    search error instead of shipping a wrong frontier.

use rlhf_mem::planner::{plan, space, Budget};
use rlhf_mem::rlhf::program::PhaseProgram;
use rlhf_mem::surrogate::{
    features, fit, plan_surrogate, FitOptions, SurrogateModel, PEAK_TARGET, TIME_TARGET,
};
use rlhf_mem::sweep::SweepRunner;

/// A battery that exercises every discrete axis the surrogate groups by:
/// 2 strategies × 4 policies × 2 algorithms × 2 sharings (incl. the
/// reward-side PERL placement) × 1 allocator.
fn battery_budget() -> Budget {
    let mut b = Budget::rtx3090_table1();
    b.steps = 1;
    b.strategies = Some(vec!["none".to_string(), "zero3".to_string()]);
    b.allocators = Some(vec!["default".to_string()]);
    b.algos = Some(vec!["ppo".to_string(), "grpo".to_string()]);
    b.sharings = Some(vec!["separate".to_string(), "perl".to_string()]);
    b
}

fn tiny_budget() -> Budget {
    let mut b = Budget::rtx3090_table1();
    b.steps = 1;
    b.strategies = Some(vec!["none".to_string(), "zero3".to_string()]);
    b.allocators = Some(vec!["default".to_string(), "expandable".to_string()]);
    b
}

#[test]
fn envelopes_strictly_bracket_every_in_sample_observation() {
    let budget = battery_budget();
    let steps = vec![1u64, 2, 3];
    let model = fit(&budget, 3, &FitOptions { steps: steps.clone() }).unwrap();
    assert!(
        model.max_rel_err <= 0.05,
        "fit quality regressed: max rel err {} above the committed CI bound",
        model.max_rel_err
    );
    // Verify through the serialized artifact, not the in-memory model:
    // the JSON roundtrip must not perturb a single coefficient.
    let text = model.to_json().to_string_pretty();
    let model = SurrogateModel::from_json_text(&text).unwrap();
    assert_eq!(model.to_json().to_string_pretty(), text);

    let candidates = space::enumerate(&budget).unwrap();
    assert_eq!(model.groups.len(), candidates.len());
    for &s in &steps {
        let mut cells = space::to_cells(&budget, &candidates);
        for cell in &mut cells {
            cell.scenario.steps = s;
        }
        let report = SweepRunner::new(3).capture_profiles(true).run(cells);
        let x = features(&budget, s);
        for (cand, cell) in candidates.iter().zip(&report.cells) {
            let g = model.group(&cand.key()).expect("every candidate has a group");
            if cell.summary.oom {
                assert!(g.oom_steps.contains(&s), "{}: OOM not recorded", cand.key());
                continue;
            }
            assert!(!g.oom_steps.contains(&s), "{}: spurious OOM record", cand.key());
            let check = |name: &str, y: f64| {
                let t = g
                    .target(name)
                    .unwrap_or_else(|| panic!("{}: missing target {name}", cand.key()));
                let p = t.predict(&x);
                assert!(
                    p - t.envelope < y && y < p + t.envelope,
                    "{} / {name} at steps {s}: observed {y} escapes ({}, {})",
                    cand.key(),
                    p - t.envelope,
                    p + t.envelope
                );
            };
            check(PEAK_TARGET, cell.summary.peak_reserved as f64);
            check(TIME_TARGET, cell.summary.total_time_us);
            let mut scn = space::candidate_scenario(&budget, cand);
            scn.steps = s;
            let program = PhaseProgram::compile(&scn);
            let profiler = cell.profiler.as_ref().expect("profiles captured");
            for (kind, peak) in profiler.phase_attribution(&program) {
                check(&format!("phase:{}", kind.name()), peak.reserved as f64);
            }
        }
    }
}

#[test]
fn frontier_is_byte_identical_for_any_jobs_on_a_narrowed_budget() {
    let budget = tiny_budget();
    let model = fit(&budget, 2, &FitOptions::for_budget(&budget)).unwrap();
    let exhaustive = plan(&budget, 2).unwrap();
    let one = plan_surrogate(&budget, 1, &model).unwrap();
    let three = plan_surrogate(&budget, 3, &model).unwrap();
    assert_eq!(one.frontier_jsonl(), exhaustive.frontier_jsonl());
    assert_eq!(three.frontier_jsonl(), exhaustive.frontier_jsonl());
    // The whole deterministic output (frontier + telemetry footer) is
    // jobs-independent too.
    assert_eq!(one.jsonl_with_telemetry(), three.jsonl_with_telemetry());
    assert!(one.simulated < one.screened);
}

#[test]
fn frontier_is_byte_identical_on_the_full_default_budget() {
    // The headline configuration: the paper's full RTX-3090 mitigation
    // space (7 strategies × 4 policies × 5 allocator configs). CI gates
    // the ≥10× simulation reduction on the shipped example budget; here
    // the pin is the identity itself plus a conservative reduction bound
    // that any sane screen clears.
    let budget = Budget::rtx3090_table1();
    let model = fit(&budget, 2, &FitOptions::for_budget(&budget)).unwrap();
    let screened = plan_surrogate(&budget, 2, &model).unwrap();
    let exhaustive = plan(&budget, 2).unwrap();
    assert_eq!(screened.frontier_jsonl(), exhaustive.frontier_jsonl());
    assert_eq!(screened.fallback, 0);
    assert!(
        screened.simulated * 2 <= screened.screened,
        "screen must cut simulations at least in half ({} of {})",
        screened.simulated,
        screened.screened
    );
    // Every frontier line of the exhaustive search appears verbatim, so
    // overhead percentages (which need pass-B baselines) agree too.
    for line in exhaustive.frontier_jsonl().lines() {
        assert!(screened.frontier_jsonl().contains(line));
    }
}

#[test]
fn uncovered_candidates_fall_back_to_simulation() {
    let mut narrow = tiny_budget();
    narrow.strategies = Some(vec!["none".to_string()]);
    let model = fit(&narrow, 2, &FitOptions::for_budget(&narrow)).unwrap();
    let wide = tiny_budget();
    let screened = plan_surrogate(&wide, 2, &model).unwrap();
    assert!(screened.fallback > 0, "zero3 groups are unknown to the artifact");
    assert_eq!(
        screened.frontier_jsonl(),
        plan(&wide, 2).unwrap().frontier_jsonl()
    );
}

#[test]
fn refuted_certificates_error_instead_of_shipping_a_wrong_frontier() {
    let budget = tiny_budget();
    let mut model = fit(&budget, 2, &FitOptions::for_budget(&budget)).unwrap();
    let exhaustive = plan(&budget, 2).unwrap();
    let frontier = exhaustive.frontier();
    assert!(frontier.len() >= 2, "test needs a multi-point frontier");
    // Tamper the fastest frontier point's peak model into "zero bytes":
    // the screen now believes it dominates the genuinely cheapest-memory
    // point, excludes it, and the simulated results must refute that
    // certificate (nothing simulated beats the true minimum peak).
    let fastest_pt = frontier
        .iter()
        .min_by(|a, b| a.summary.total_time_us.total_cmp(&b.summary.total_time_us))
        .unwrap();
    let cheapest_pt = frontier
        .iter()
        .min_by_key(|o| o.summary.peak_reserved)
        .unwrap();
    let fastest = fastest_pt.candidate.key();
    // Single-sample fits pin every envelope at exactly the 1.0 floor, so
    // the forged witness dominates the cheapest point only if their time
    // gap exceeds the two envelopes — guaranteed on this budget.
    assert_ne!(fastest, cheapest_pt.candidate.key());
    assert!(cheapest_pt.summary.total_time_us - fastest_pt.summary.total_time_us > 2.0);
    let g = model
        .groups
        .iter_mut()
        .find(|g| g.key == fastest)
        .expect("fitted group");
    let peak = g
        .targets
        .iter_mut()
        .find(|(n, _)| n == PEAK_TARGET)
        .expect("peak target");
    peak.1.coefs = [0.0; 6];
    let err = plan_surrogate(&budget, 2, &model).unwrap_err();
    assert!(err.contains("stale"), "unexpected error text: {err}");
    assert!(err.contains("rlhf-mem fit"), "error must say how to recover: {err}");
}

#[test]
fn certified_oom_cells_are_never_simulated_but_stay_off_the_frontier() {
    // Starve the capacity so the heavy strategies OOM: the artifact then
    // certifies those cells and the screen must reproduce the exhaustive
    // frontier without replaying them.
    let mut budget = tiny_budget();
    budget.capacity = 8 * 1024 * 1024 * 1024;
    let model = fit(&budget, 2, &FitOptions::for_budget(&budget)).unwrap();
    let oom_groups = model.groups.iter().filter(|g| !g.oom_steps.is_empty()).count();
    let screened = plan_surrogate(&budget, 2, &model).unwrap();
    let exhaustive = plan(&budget, 2).unwrap();
    assert_eq!(screened.frontier_jsonl(), exhaustive.frontier_jsonl());
    if oom_groups > 0 {
        assert!(
            screened.outcomes.iter().all(|o| !o.summary.oom),
            "certified-OOM cells must not be re-simulated"
        );
    }
}
