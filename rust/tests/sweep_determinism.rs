//! Sweep-engine integration tests: a grid run with `--jobs 1` and
//! `--jobs 4` must produce byte-identical per-cell summaries (same seeds
//! → same traces → same allocator stats), and the engine must reproduce
//! the serial `run_scenario` path exactly.

use rlhf_mem::experiment::{run_scenario, RTX3090_HBM};
use rlhf_mem::frameworks::FrameworkKind;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::sweep::{SeedPolicy, SweepGrid, SweepRunner};

fn grid() -> SweepGrid {
    SweepGrid::new()
        .frameworks([FrameworkKind::DeepSpeedChat, FrameworkKind::ColossalChat])
        .strategies([
            ("None", StrategyConfig::none()),
            ("ZeRO-3", StrategyConfig::zero3()),
        ])
        .policies([EmptyCachePolicy::Never, EmptyCachePolicy::AfterBoth])
        .steps(1)
}

#[test]
fn jobs1_and_jobs4_are_byte_identical() {
    let cells = grid().build().unwrap();
    assert_eq!(cells.len(), 8);
    let serial = SweepRunner::new(1).run(cells.clone());
    let pooled = SweepRunner::new(4).run(cells);
    assert_eq!(
        serial.jsonl(),
        pooled.jsonl(),
        "per-cell summaries must not depend on the worker count"
    );
    assert_eq!(pooled.jobs, 4);
}

#[test]
fn jitter_scenarios_are_reproducible_across_worker_counts() {
    // ColossalChat samples response lengths from the cell seed; distinct
    // per-cell seeds must still give identical results for jobs 1 vs 4.
    let cells = grid().seeds(SeedPolicy::PerCell(7)).build().unwrap();
    let serial = SweepRunner::new(1).run(cells.clone());
    let pooled = SweepRunner::new(4).run(cells);
    assert_eq!(serial.jsonl(), pooled.jsonl());
}

#[test]
fn engine_matches_the_serial_experiment_path() {
    // One cell of the Table-1 grid vs a hand-built run_scenario call: the
    // sweep engine must reproduce the exact same numbers.
    let cells = grid().build().unwrap();
    let report = SweepRunner::new(2).run(cells);
    let cell = report
        .get("DeepSpeed-Chat/OPT/ZeRO-3/full/never")
        .expect("cell present");

    let mut scn = rlhf_mem::rlhf::sim::SimScenario::deepspeed_opt(
        StrategyConfig::zero3(),
        EmptyCachePolicy::Never,
    );
    scn.steps = 1;
    let reference = run_scenario(&scn, RTX3090_HBM);
    assert_eq!(cell.summary, reference.summary);
}

#[test]
fn fixed_seed_grid_reproduces_itself() {
    let cells = grid().build().unwrap();
    let a = SweepRunner::new(3).run(cells.clone());
    let b = SweepRunner::new(3).run(cells);
    assert_eq!(a.jsonl(), b.jsonl());
}

#[test]
fn sharing_axis_grid_is_jobs_deterministic() {
    use rlhf_mem::rlhf::program::Sharing;
    let cells = grid().seeds(SeedPolicy::PerCell(7)).sharings(Sharing::ALL).build().unwrap();
    assert_eq!(cells.len(), 8 * Sharing::ALL.len());
    // Non-separate cells carry the placement as an extra key component,
    // and per-cell seeds ignore it (same scenario, different placement →
    // same response lengths).
    assert_eq!(cells[0].key, "DeepSpeed-Chat/OPT/None/full/never");
    assert_eq!(cells[1].key, "DeepSpeed-Chat/OPT/None/full/never/lora");
    assert_eq!(cells[0].scenario.seed, cells[1].scenario.seed);
    let serial = SweepRunner::new(1).run(cells.clone());
    let pooled = SweepRunner::new(4).run(cells);
    assert_eq!(
        serial.jsonl(),
        pooled.jsonl(),
        "the sharing axis must not break --jobs determinism"
    );
    // The JSONL carries the placement for every cell (line 0 is the
    // schema header).
    assert!(serial
        .jsonl()
        .lines()
        .skip(1)
        .all(|l| l.contains("\"sharing\":")));
}

#[test]
fn algo_axis_grid_is_jobs_deterministic() {
    use rlhf_mem::rlhf::program::Algo;
    let cells = grid().algos(Algo::ALL).build().unwrap();
    assert_eq!(cells.len(), 8 * Algo::ALL.len());
    // Non-PPO cells carry the algo as an extra key component.
    assert_eq!(cells[0].key, "DeepSpeed-Chat/OPT/None/full/never");
    assert_eq!(cells[1].key, "DeepSpeed-Chat/OPT/None/full/never/grpo");
    let serial = SweepRunner::new(1).run(cells.clone());
    let pooled = SweepRunner::new(4).run(cells);
    assert_eq!(
        serial.jsonl(),
        pooled.jsonl(),
        "the algo axis must not break --jobs determinism"
    );
    // The JSONL carries the algo for every cell (line 0 is the schema
    // header).
    assert!(serial
        .jsonl()
        .lines()
        .skip(1)
        .all(|l| l.contains("\"algo\":")));
}
