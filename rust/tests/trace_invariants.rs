//! Property-style trace invariants over the full algorithm × strategy ×
//! mode grid (plus placement subsets and the model-sharing axis): every
//! trace the interpreter emits must uphold handle discipline, lifetime
//! closure at the final StepEnd, and a phase-mark sequence exactly
//! matching its compiled [`PhaseProgram`] — only phases of hosted,
//! algorithm-active roles, in program order. Shared frozen backbones
//! additionally must allocate each shared weight handle exactly once
//! (handle discipline makes a double allocation a hard error) and keep
//! adapter-only optimizer state at or under the full fine-tune bill.

use rlhf_mem::coordinator::PlacementPlan;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::program::{Algo, PhaseProgram, Sharing};
use rlhf_mem::rlhf::sim::{build_trace, ScenarioMode, SimScenario};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::trace::analysis::check_invariants;
use rlhf_mem::trace::{Tag, Trace, TraceOp};

fn check(scn: &SimScenario, context: &str) {
    let program = PhaseProgram::compile(scn);
    let trace = build_trace(scn);
    check_invariants(&trace, &program.step_phases())
        .unwrap_or_else(|e| panic!("{context}: {e}"));
}

#[test]
fn every_algo_strategy_mode_cell_upholds_the_invariants() {
    for algo in Algo::ALL {
        for (label, strategy) in StrategyConfig::table1_deepspeed_rows() {
            for mode in ScenarioMode::ALL {
                let mut scn =
                    SimScenario::deepspeed_opt(strategy, EmptyCachePolicy::AfterBoth);
                scn.steps = 1;
                scn.mode = mode;
                scn.algo = algo;
                check(
                    &scn,
                    &format!("ds/{label}/{}/{}", mode.name(), algo.name()),
                );
            }
        }
    }
}

#[test]
fn colossal_offload_cycles_uphold_the_invariants() {
    // ColossalChat swaps scorers to host during training and re-uploads
    // next step — two steps exercise the full offload/upload cycle, with
    // length jitter varying every step's shapes.
    for algo in Algo::ALL {
        for mode in ScenarioMode::ALL {
            let mut scn =
                SimScenario::colossal_opt(StrategyConfig::zero3(), EmptyCachePolicy::AfterInference);
            scn.steps = 2;
            scn.mode = mode;
            scn.algo = algo;
            check(&scn, &format!("cc/zero3/{}/{}", mode.name(), algo.name()));
        }
    }
}

#[test]
fn sharing_grid_upholds_the_invariants() {
    for sharing in Sharing::ALL {
        for algo in Algo::ALL {
            for (label, strategy) in StrategyConfig::table1_deepspeed_rows() {
                let mut scn =
                    SimScenario::deepspeed_opt(strategy, EmptyCachePolicy::AfterBoth);
                scn.steps = 1;
                scn.algo = algo;
                scn.sharing = sharing;
                check(
                    &scn,
                    &format!("ds/{label}/{}/{}", algo.name(), sharing.name()),
                );
            }
        }
    }
}

#[test]
fn sharing_survives_colossal_offload_cycles() {
    // ColossalChat swaps scorers to host during training; under sharing
    // the scorers' device tensors are adapters or value heads, never the
    // backbone another role still needs — two steps exercise the full
    // offload/upload cycle per placement.
    for sharing in Sharing::ALL {
        for algo in Algo::ALL {
            let mut scn = SimScenario::colossal_opt(
                StrategyConfig::zero3(),
                EmptyCachePolicy::AfterInference,
            );
            scn.steps = 2;
            scn.algo = algo;
            scn.sharing = sharing;
            check(&scn, &format!("cc/zero3/{}/{}", algo.name(), sharing.name()));
        }
    }
}

fn alloc_bytes(t: &Trace, want: Tag) -> u64 {
    t.ops
        .iter()
        .filter_map(|op| match op {
            TraceOp::Alloc { tag, bytes, .. } if *tag == want => Some(*bytes),
            _ => None,
        })
        .sum()
}

fn alloc_count(t: &Trace, want: Tag) -> usize {
    t.ops
        .iter()
        .filter(|op| matches!(op, TraceOp::Alloc { tag, .. } if *tag == want))
        .count()
}

#[test]
fn shared_backbones_allocate_each_weight_handle_once() {
    // One frozen backbone hosts several roles, so shared placements emit
    // strictly fewer Param allocations than full replicas — and hydra
    // (one backbone for everything) never more than lora (one per pair).
    // check_invariants (above) already makes re-allocating a live handle
    // a hard error, so fewer allocations means each shared handle was
    // created exactly once.
    for algo in Algo::ALL {
        let count = |sharing: Sharing| {
            let mut scn =
                SimScenario::deepspeed_opt(StrategyConfig::none(), EmptyCachePolicy::Never);
            scn.steps = 1;
            scn.algo = algo;
            scn.sharing = sharing;
            alloc_count(&build_trace(&scn), Tag::Param)
        };
        let separate = count(Sharing::Separate);
        let lora = count(Sharing::Lora);
        let hydra = count(Sharing::Hydra);
        assert!(lora < separate, "{}: lora {lora} vs separate {separate}", algo.name());
        // DPO's two-role cast makes hydra and lora the same placement.
        assert!(hydra <= lora, "{}: hydra {hydra} vs lora {lora}", algo.name());
    }
}

#[test]
fn adapter_optimizer_state_never_exceeds_full_fine_tune() {
    for algo in Algo::ALL {
        for (label, strategy) in StrategyConfig::table1_deepspeed_rows() {
            let opt = |sharing: Sharing| {
                let mut scn =
                    SimScenario::deepspeed_opt(strategy, EmptyCachePolicy::Never);
                scn.steps = 1;
                scn.algo = algo;
                scn.sharing = sharing;
                alloc_bytes(&build_trace(&scn), Tag::OptState)
            };
            let separate = opt(Sharing::Separate);
            for sharing in [Sharing::Lora, Sharing::Hydra, Sharing::FrozenShared] {
                let shared = opt(sharing);
                assert!(
                    shared <= separate,
                    "ds/{label}/{}/{}: adapter opt state {shared} exceeds full {separate}",
                    algo.name(),
                    sharing.name()
                );
            }
        }
    }
}

#[test]
fn placement_subsets_uphold_the_invariants() {
    for algo in Algo::ALL {
        let mut base = SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never);
        base.steps = 2;
        base.algo = algo;
        for plan in PlacementPlan::presets(3) {
            for g in 0..plan.gpus() as usize {
                let scn = plan.scenario_for_gpu(&base, g);
                check(&scn, &format!("{}/gpu{g}/{}", plan.name, algo.name()));
            }
        }
    }
}
