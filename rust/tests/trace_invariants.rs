//! Property-style trace invariants over the full algorithm × strategy ×
//! mode grid (plus placement subsets): every trace the interpreter emits
//! must uphold handle discipline, lifetime closure at the final StepEnd,
//! and a phase-mark sequence exactly matching its compiled
//! [`PhaseProgram`] — only phases of hosted, algorithm-active roles, in
//! program order.

use rlhf_mem::coordinator::PlacementPlan;
use rlhf_mem::policy::EmptyCachePolicy;
use rlhf_mem::rlhf::program::{Algo, PhaseProgram};
use rlhf_mem::rlhf::sim::{build_trace, ScenarioMode, SimScenario};
use rlhf_mem::strategies::StrategyConfig;
use rlhf_mem::trace::analysis::check_invariants;

fn check(scn: &SimScenario, context: &str) {
    let program = PhaseProgram::compile(scn);
    let trace = build_trace(scn);
    check_invariants(&trace, &program.step_phases())
        .unwrap_or_else(|e| panic!("{context}: {e}"));
}

#[test]
fn every_algo_strategy_mode_cell_upholds_the_invariants() {
    for algo in Algo::ALL {
        for (label, strategy) in StrategyConfig::table1_deepspeed_rows() {
            for mode in ScenarioMode::ALL {
                let mut scn =
                    SimScenario::deepspeed_opt(strategy, EmptyCachePolicy::AfterBoth);
                scn.steps = 1;
                scn.mode = mode;
                scn.algo = algo;
                check(
                    &scn,
                    &format!("ds/{label}/{}/{}", mode.name(), algo.name()),
                );
            }
        }
    }
}

#[test]
fn colossal_offload_cycles_uphold_the_invariants() {
    // ColossalChat swaps scorers to host during training and re-uploads
    // next step — two steps exercise the full offload/upload cycle, with
    // length jitter varying every step's shapes.
    for algo in Algo::ALL {
        for mode in ScenarioMode::ALL {
            let mut scn =
                SimScenario::colossal_opt(StrategyConfig::zero3(), EmptyCachePolicy::AfterInference);
            scn.steps = 2;
            scn.mode = mode;
            scn.algo = algo;
            check(&scn, &format!("cc/zero3/{}/{}", mode.name(), algo.name()));
        }
    }
}

#[test]
fn placement_subsets_uphold_the_invariants() {
    for algo in Algo::ALL {
        let mut base = SimScenario::deepspeed_opt(StrategyConfig::zero3(), EmptyCachePolicy::Never);
        base.steps = 2;
        base.algo = algo;
        for plan in PlacementPlan::presets(3) {
            for g in 0..plan.gpus() as usize {
                let scn = plan.scenario_for_gpu(&base, g);
                check(&scn, &format!("{}/gpu{g}/{}", plan.name, algo.name()));
            }
        }
    }
}
